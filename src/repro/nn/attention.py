"""Grouped-query multi-head attention with KV cache, cross-attention, SubLN,
and BitLinear projections.

Layouts: activations [B, S, D]; per-head tensors [B, S, H, Dh]; KV caches
[B, Smax, Hkv, Dh].  All softmax math in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.bitlinear import BitLinear, SubLN
from repro.nn.layers import RMSNorm, apply_rope
from repro.nn.module import DTypePolicy, DEFAULT_POLICY, split_keys

Params = dict
NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class Attention:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    cross: bool = False          # kv comes from encoder memory, never causal
    logit_softcap: float = 0.0
    subln: bool = False          # SubLN before the output projection (Eq. 4)
    # perf knobs (§Perf hillclimb; baseline = paper-faithful naive):
    #   scores_dtype: fp32 scores (baseline) vs bf16 scores w/ fp32 softmax
    #   impl: "dense" materializes [S,T] scores; "blocked" streams KV blocks
    #         flash-style (never materializes S×T in HBM)
    scores_dtype: str = "float32"
    impl: str = "dense"
    block_kv: int = 1024
    quant: Q.QuantConfig = Q.FP
    policy: DTypePolicy = DEFAULT_POLICY

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    # -- submodules ----------------------------------------------------------

    def _wq(self):
        return BitLinear(self.d_model, self.q_dim, self.qkv_bias, self.quant,
                         ("embed", "heads"), self.policy)

    def _wk(self):
        return BitLinear(self.d_model, self.kv_dim, self.qkv_bias, self.quant,
                         ("embed", "kv_heads"), self.policy)

    def _wv(self):
        return BitLinear(self.d_model, self.kv_dim, self.qkv_bias, self.quant,
                         ("embed", "kv_heads"), self.policy)

    def _wo(self):
        return BitLinear(self.q_dim, self.d_model, False, self.quant,
                         ("heads", "embed"), self.policy)

    def _subln(self):
        return SubLN(self.q_dim, axis_name="heads", policy=self.policy)

    def _qnorm(self):
        return RMSNorm(self.head_dim, axis_name="head_dim", policy=self.policy)

    def init(self, key) -> Params:
        ks = split_keys(key, ["wq", "wk", "wv", "wo", "subln", "qn", "kn"])
        p: Params = {
            "wq": self._wq().init(ks["wq"]),
            "wk": self._wk().init(ks["wk"]),
            "wv": self._wv().init(ks["wv"]),
            "wo": self._wo().init(ks["wo"]),
        }
        if self.subln:
            p["subln"] = self._subln().init(ks["subln"])
        if self.qk_norm:
            p["q_norm"] = self._qnorm().init(ks["qn"])
            p["k_norm"] = self._qnorm().init(ks["kn"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {
            "wq": self._wq().param_axes(),
            "wk": self._wk().param_axes(),
            "wv": self._wv().param_axes(),
            "wo": self._wo().param_axes(),
        }
        if self.subln:
            ax["subln"] = self._subln().param_axes()
        if self.qk_norm:
            ax["q_norm"] = self._qnorm().param_axes()
            ax["k_norm"] = self._qnorm().param_axes()
        return ax

    # -- projections ----------------------------------------------------------

    def _project_q(self, p: Params, x: jax.Array, positions) -> jax.Array:
        b, s, _ = x.shape
        q = self._wq().apply(p["wq"], x).reshape(b, s, self.n_heads, self.head_dim)
        if self.qk_norm:
            q = self._qnorm().apply(p["q_norm"], q)
        if self.use_rope and not self.cross:
            q = apply_rope(q, positions, self.rope_theta)
        return q

    def _project_kv(self, p: Params, x: jax.Array, positions) -> Tuple[jax.Array, jax.Array]:
        b, s, _ = x.shape
        k = self._wk().apply(p["wk"], x).reshape(b, s, self.n_kv_heads, self.head_dim)
        v = self._wv().apply(p["wv"], x).reshape(b, s, self.n_kv_heads, self.head_dim)
        if self.qk_norm:
            k = self._qnorm().apply(p["k_norm"], k)
        if self.use_rope and not self.cross:
            k = apply_rope(k, positions, self.rope_theta)
        return k, v

    # -- attention core --------------------------------------------------------

    def _attend(self, q: jax.Array, k: jax.Array, v: jax.Array,
                mask: Optional[jax.Array], kv_layout: str = "bshd") -> jax.Array:
        """q [B,S,Hq,Dh]; k/v [B,T,Hkv,Dh] ("bshd") or pre-transposed
        [B,Hkv,T,Dh] ("bhsd", the cache layout — avoids a full-cache
        transpose copy every decode step); mask [B,1,S,T] bool (True=keep)."""
        if self.impl == "blocked" and q.shape[1] > 1 and kv_layout == "bshd":
            return self._attend_blocked(q, k, v, mask)
        b, s, hq, dh = q.shape
        g = hq // self.n_kv_heads
        sd = jnp.dtype(self.scores_dtype)
        # transpose small [.., S, Dh] head tensors up front so both score and
        # context dots produce their natural layouts (no S×T transposes)
        qg = q.reshape(b, s, self.n_kv_heads, g, dh).transpose(0, 2, 3, 1, 4)
        if kv_layout == "bshd":
            kf = k.transpose(0, 2, 1, 3)                       # [b,kv,t,dh]
            vf = v.transpose(0, 2, 1, 3)
        else:
            kf, vf = k, v
        t = kf.shape[2]
        scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(sd), kf.astype(sd),
                            preferred_element_type=jnp.float32)
        scores = scores / jnp.sqrt(dh).astype(jnp.float32)
        if self.logit_softcap > 0.0:
            scores = self.logit_softcap * jnp.tanh(scores / self.logit_softcap)
        if mask is not None:
            scores = jnp.where(mask[:, :, None], scores,
                               jnp.asarray(NEG_INF if sd == jnp.float32
                                           else -3e38, jnp.float32))
        if sd != jnp.float32:
            # bf16 scores mode: keep fp32 MXU accumulation but store the
            # [S,T] product in bf16 — halves the dominant prefill tensor.
            # Softmax stability: subtract the row max first (exact in bf16).
            m = jnp.max(scores, axis=-1, keepdims=True)
            e = jnp.exp((scores - m).astype(sd))          # bf16 exp tensor
            z = jnp.sum(e, axis=-1, keepdims=True, dtype=jnp.float32)
            w = (e / z.astype(sd)).astype(sd)
        else:
            w = jax.nn.softmax(scores, axis=-1).astype(sd)
        out = jnp.einsum("bkgst,bktd->bkgsd", w, vf.astype(sd),
                         preferred_element_type=jnp.float32)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh).astype(v.dtype)

    def _attend_blocked(self, q: jax.Array, k: jax.Array, v: jax.Array,
                        mask: Optional[jax.Array]) -> jax.Array:
        """Flash-style: stream KV blocks with an online softmax; peak memory
        O(S·block) instead of O(S·T).  Gradients via recompute (the scan body
        is cheap to rebuild); causal masking by position arithmetic."""
        b, s, hq, dh = q.shape
        t = k.shape[1]
        g = hq // self.n_kv_heads
        blk = min(self.block_kv, t)
        nb = -(-t // blk)
        pad = nb * blk - t
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        colmask_full = None
        if mask is not None:
            colmask_full = jnp.pad(mask, ((0, 0), (0, 0), (0, 0), (0, pad)))

        qg = (q.reshape(b, s, self.n_kv_heads, g, dh)
              .transpose(0, 2, 3, 1, 4).astype(jnp.float32))   # [b,kv,g,s,dh]
        scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

        def body(carry, i):
            m, z, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, i * blk, blk, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, i * blk, blk, 1)
            kb = kb.transpose(0, 2, 1, 3).astype(jnp.float32)  # [b,kv,blk,dh]
            vb = vb.transpose(0, 2, 1, 3).astype(jnp.float32)
            sc = jnp.einsum("bkgsd,bktd->bkgst", qg, kb) * scale
            if self.logit_softcap > 0.0:
                sc = self.logit_softcap * jnp.tanh(sc / self.logit_softcap)
            valid = (i * blk + jnp.arange(blk)) < t
            if colmask_full is not None:
                cm = jax.lax.dynamic_slice_in_dim(colmask_full, i * blk, blk, 3)
                sc = jnp.where(cm[:, :, None] & valid, sc, NEG_INF)
            else:
                sc = jnp.where(valid, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            c = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new)
            z_new = z * c + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * c + jnp.einsum("bkgst,bktd->bkgsd", p, vb)
            return (m_new, z_new, acc_new), None

        m0 = jnp.full((b, self.n_kv_heads, g, s, 1), NEG_INF, jnp.float32)
        z0 = jnp.zeros((b, self.n_kv_heads, g, s, 1), jnp.float32)
        a0 = jnp.zeros((b, self.n_kv_heads, g, s, dh), jnp.float32)
        (m, z, acc), _ = jax.lax.scan(body, (m0, z0, a0), jnp.arange(nb))
        out = acc / jnp.maximum(z, 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh).astype(v.dtype)

    # -- full-sequence forward (train / prefill) -------------------------------

    def apply(self, p: Params, x: jax.Array,
              positions: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              memory_mask: Optional[jax.Array] = None,
              collect_states: bool = False,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]], Params]:
        """Returns (y, aux_states, kv) where kv = {"k","v"} for cache seeding.

        aux_states (when collect_states): {"q","k","v"} each [B, H, S, Dh] with
        kv heads repeated to n_heads — the layout Algorithm 1 distills.
        """
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        src = memory if self.cross else x
        src_pos = None if self.cross else positions
        q = self._project_q(p, x, positions)
        k, v = self._project_kv(p, src, src_pos)

        t = k.shape[1]
        if self.cross:
            mask = None if memory_mask is None else memory_mask[:, None, None, :]
        elif self.causal:
            mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None]
            mask = jnp.broadcast_to(mask, (b, 1, s, t))
        else:
            mask = None
        ctx = self._attend(q, k, v, mask)

        flat = ctx.reshape(b, s, self.q_dim)
        if self.subln:
            flat = self._subln().apply(p["subln"], flat)
        y = self._wo().apply(p["wo"], flat)

        aux = None
        if collect_states:
            g = self.n_heads // self.n_kv_heads
            rep = lambda a: jnp.repeat(a, g, axis=2) if g > 1 else a
            aux = {
                "q": q.transpose(0, 2, 1, 3),
                "k": rep(k).transpose(0, 2, 1, 3),
                "v": rep(v).transpose(0, 2, 1, 3),
            }
        return y, aux, {"k": k, "v": v}

    # -- single-token decode with cache ----------------------------------------

    def decode(self, p: Params, x: jax.Array, cache: Params,
               cache_index: jax.Array,
               memory: Optional[jax.Array] = None,
               block_tables: Optional[jax.Array] = None,
               attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """x: [B, 1, D]; cache: {"k","v"} [B, Hkv, Smax, Dh] (attention
        layout — no per-step transpose of the cache); returns (y, cache).

        ``cache_index`` is a scalar (all rows at the same depth) or an int32
        [B] vector of per-row write positions — continuous batching runs rows
        at different sequence depths in one step; each row writes its KV at
        its own index and attends only to its own positions <= index.

        ``block_tables`` (int32 [B, L]) switches the cache to the *paged*
        layout: {"k","v"} become shared pools [num_blocks, Hkv, bs, Dh] and
        logical position ``i`` of row ``b`` lives at pool block
        ``block_tables[b, i // bs]``, offset ``i % bs``.  ``attn_impl``
        selects how that layout is attended:

        * ``"gather"`` — scatter the new KV, then gather the whole table
          into a dense [B, Hkv, L*bs, Dh] window and run dense masked
          attention (the fallback; bandwidth is worst-case O(B * L * bs));
        * ``"fused"`` — the Pallas kernel streams each row's resident
          blocks straight out of the pools with an online-softmax carry and
          fuses the new-KV scatter (kernels/paged_attention); KV bytes read
          per step are O(tokens resident).  Scores are always fp32 here
          (``scores_dtype`` applies to the non-kernel paths)."""
        b = x.shape[0]
        idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32).reshape(-1),
                               (b,))
        positions = idx[:, None]
        q = self._project_q(p, x, positions)
        if self.cross:
            # cross-attention cache holds the projected encoder memory (static).
            k, v = cache["k"], cache["v"]
            mask = None
        elif block_tables is not None and attn_impl == "fused":
            from repro.kernels.paged_attention import ops as pa_ops
            k_new, v_new = self._project_kv(p, x, positions)  # [B, 1, Hkv, Dh]
            ctx, pool_k, pool_v = pa_ops.paged_attention_decode(
                q[:, 0], k_new[:, 0], v_new[:, 0], cache["k"], cache["v"],
                block_tables, idx, softcap=self.logit_softcap)
            return self._decode_out(p, ctx[:, None]), {"k": pool_k,
                                                       "v": pool_v}
        elif block_tables is not None:
            if attn_impl != "gather":
                raise ValueError(f"unknown attn_impl {attn_impl!r} "
                                 "(expected 'fused' or 'gather')")
            k, v, cache, mask = self._paged_update(
                p, x, cache, idx, block_tables, positions)
        else:
            k_new, v_new = self._project_kv(p, x, positions)
            k_new = k_new.transpose(0, 2, 1, 3)  # [b,kv,1,dh] (tiny)
            v_new = v_new.transpose(0, 2, 1, 3)

            def put(row_cache, row_new, row_idx):
                return jax.lax.dynamic_update_slice_in_dim(
                    row_cache, row_new, row_idx, axis=1)

            k = jax.vmap(put)(cache["k"], k_new.astype(cache["k"].dtype), idx)
            v = jax.vmap(put)(cache["v"], v_new.astype(cache["v"].dtype), idx)
            cache = {"k": k, "v": v}
            t = k.shape[2]
            mask = (jnp.arange(t)[None, :] <= idx[:, None])[:, None, None, :]
            mask = jnp.broadcast_to(mask, (b, 1, 1, t))
        ctx = self._attend(q, k, v, mask, kv_layout="bhsd")
        return self._decode_out(p, ctx), cache

    def _decode_out(self, p: Params, ctx: jax.Array) -> jax.Array:
        """ctx [B, S, Hq, Dh] -> SubLN + output projection."""
        b, s = ctx.shape[:2]
        flat = ctx.reshape(b, s, self.q_dim)
        if self.subln:
            flat = self._subln().apply(p["subln"], flat)
        return self._wo().apply(p["wo"], flat)

    # -- chunked prefill/decode with paged cache -------------------------------

    def decode_chunk(self, p: Params, x: jax.Array, cache: Params,
                     start: jax.Array, lens: jax.Array,
                     block_tables: jax.Array,
                     attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """Chunked-prefill step over the *paged* cache: x [B, T, D] holds a
        chunk of T tokens per row; token ``j`` of row ``b`` is written at
        cache position ``start[b] + j`` (valid iff ``j < lens[b]``, pad
        positions are never written) and attends stored positions
        ``<= start[b] + j`` — the resident prefix (trie-shared blocks
        included, read in place) plus the chunk's own causal prefix.  Decode
        rows are the ``lens == 1`` case, so one call serves steps that mix
        prefilling and decoding rows (serving/engine.py's fused chunk step).

        ``attn_impl`` selects the implementation exactly as in ``decode``:
        ``"fused"`` streams resident blocks through the Pallas chunk kernel
        (kernels/paged_prefill) with the chunk-KV scatter fused via aliased
        pool outputs; ``"gather"`` scatters the chunk KV, materializes the
        dense block-table window, and runs masked dense attention.  Scores
        are always fp32 here."""
        if self.cross:
            raise ValueError("decode_chunk is self-attention only (the paged "
                             "cache has no cross-attention layout)")
        b, t, _ = x.shape
        start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1),
                                 (b,))
        lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32).reshape(-1), (b,))
        positions = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
        q = self._project_q(p, x, positions)            # [B, T, Hq, Dh]
        k_new, v_new = self._project_kv(p, x, positions)
        if attn_impl == "fused":
            from repro.kernels.paged_prefill import ops as pp_ops
            ctx, pool_k, pool_v = pp_ops.paged_prefill_chunk(
                q, k_new, v_new, cache["k"], cache["v"], block_tables,
                start, lens, softcap=self.logit_softcap)
            return self._decode_out(p, ctx), {"k": pool_k, "v": pool_v}
        if attn_impl != "gather":
            raise ValueError(f"unknown attn_impl {attn_impl!r} "
                             "(expected 'fused' or 'gather')")
        pool_k, pool_v = cache["k"], cache["v"]         # [N, Hkv, bs, Dh]
        bs = pool_k.shape[2]
        nlog = block_tables.shape[1]
        valid = jnp.arange(t, dtype=jnp.int32)[None] < lens[:, None]
        blk = jnp.minimum(positions // bs, nlog - 1)
        bid = jnp.take_along_axis(block_tables, blk, axis=1)       # [B, T]
        # pad rows are discarded to the trash block (0, serving/paged.py) —
        # their write must not land in an owned block
        bid = jnp.where(valid, bid, 0)
        off = positions % bs
        kf = k_new.reshape(b * t, self.n_kv_heads, self.head_dim)
        vf = v_new.reshape(b * t, self.n_kv_heads, self.head_dim)
        pool_k = pool_k.at[bid.reshape(-1), :, off.reshape(-1)].set(
            kf.astype(pool_k.dtype))
        pool_v = pool_v.at[bid.reshape(-1), :, off.reshape(-1)].set(
            vf.astype(pool_v.dtype))
        k = pool_k[block_tables]                  # [B, L, Hkv, bs, Dh]
        v = pool_v[block_tables]
        k = k.transpose(0, 2, 1, 3, 4).reshape(
            b, self.n_kv_heads, nlog * bs, self.head_dim)
        v = v.transpose(0, 2, 1, 3, 4).reshape(
            b, self.n_kv_heads, nlog * bs, self.head_dim)
        tkv = nlog * bs
        mask = (jnp.arange(tkv, dtype=jnp.int32)[None, None]
                <= positions[:, :, None])[:, None]     # [B, 1, T, L*bs]
        ctx = self._attend(q, k, v, mask, kv_layout="bhsd")
        return self._decode_out(p, ctx), {"k": pool_k, "v": pool_v}

    def _paged_update(self, p: Params, x: jax.Array, cache: Params,
                      idx: jax.Array, block_tables: jax.Array,
                      positions: jax.Array):
        """Scatter the new KV into the row's owned pool block, then gather
        the row's block table into a contiguous [B, Hkv, L*bs, Dh] window.

        Idle rows point every table entry at the trash block (block 0); their
        scatter collides only with other idle rows and their gathered garbage
        is discarded by the caller, so no occupancy branch is traced."""
        b = idx.shape[0]
        pool_k, pool_v = cache["k"], cache["v"]   # [N, Hkv, bs, Dh]
        bs = pool_k.shape[2]
        nlog = block_tables.shape[1]
        k_new, v_new = self._project_kv(p, x, positions)   # [B, 1, Hkv, Dh]
        k_new, v_new = k_new[:, 0], v_new[:, 0]            # [B, Hkv, Dh]
        # the caller may pass a table truncated to the active batch's depth
        # (engine buckets the width to bound retraces); idle rows park at
        # max_len - 1, beyond such a window — their rows are all trash
        # block, so clamping keeps their (discarded) write deterministic
        # instead of relying on platform-defined out-of-bounds gather
        blk = jnp.minimum(idx // bs, nlog - 1)
        bid = jnp.take_along_axis(block_tables, blk[:, None], 1)[:, 0]
        off = idx % bs
        # advanced indices split by the Hkv slice -> result dims [B, Hkv, Dh]
        pool_k = pool_k.at[bid, :, off].set(k_new.astype(pool_k.dtype))
        pool_v = pool_v.at[bid, :, off].set(v_new.astype(pool_v.dtype))
        k = pool_k[block_tables]                  # [B, L, Hkv, bs, Dh]
        v = pool_v[block_tables]
        k = k.transpose(0, 2, 1, 3, 4).reshape(
            b, self.n_kv_heads, nlog * bs, self.head_dim)
        v = v.transpose(0, 2, 1, 3, 4).reshape(
            b, self.n_kv_heads, nlog * bs, self.head_dim)
        t = nlog * bs
        mask = (jnp.arange(t)[None, :] <= idx[:, None])[:, None, None, :]
        mask = jnp.broadcast_to(mask, (b, 1, 1, t))
        return k, v, {"k": pool_k, "v": pool_v}, mask

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        shape = (batch, self.n_kv_heads, max_len, self.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    @staticmethod
    def cache_axes() -> Params:
        return {"k": ("batch", "kv_heads", "kv_seq", "head_dim"),
                "v": ("batch", "kv_heads", "kv_seq", "head_dim")}
