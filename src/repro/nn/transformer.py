"""Transformer blocks and scanned stacks.

A *stack* is ``repeats`` copies of a ``pattern`` (tuple of LayerSpec).  Params
for each pattern position are stacked over the repeat dim and the stack runs
under ``jax.lax.scan`` (small HLO, fast SPMD partitioning, remat-friendly).
Heterogeneous archs map naturally: jamba = pattern of 8 (1 attn + 7 mamba,
alternating MoE), llama-3.2-vision = pattern of 5 (4 self + 1 cross), whisper
decoder = pattern of 1 with fused self+cross block.

QKV states for attention-relation distillation (Algorithm 1) are harvested
from a single (repeat, position) without materializing all layers' states:
the scan carries one [3, B, H, S, Dh] buffer that is overwritten only on the
selected repeat.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.nn.attention import Attention
from repro.nn.layers import RMSNorm
from repro.nn.mlp import GatedMLP
from repro.nn.moe import MoEMLP
from repro.nn.module import DTypePolicy, DEFAULT_POLICY, split_keys
from repro.nn.ssm import Mamba2Block

Params = dict


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # "attn" | "attn_cross" | "cross" | "mamba"
    ffn: str = "dense"         # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    d_model: int
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 0
    activation: str = "silu"
    mlp_gated: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    logit_softcap: float = 0.0
    attn_scores_dtype: str = "float32"
    attn_impl: str = "dense"        # "dense" | "blocked" (flash-style)
    block_kv: int = 1024            # KV block length for the blocked impl
    seq_shard_activations: bool = False   # Megatron-SP residual sharding
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_group_size: int = 2048
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # BitDistill stage-1
    subln: bool = False
    rms_eps: float = 1e-6
    quant: Q.QuantConfig = Q.FP
    policy: DTypePolicy = DEFAULT_POLICY


class Block:
    """One residual block: pre-norm mixer + pre-norm FFN."""

    def __init__(self, cfg: BlockConfig, spec: LayerSpec):
        self.cfg, self.spec = cfg, spec
        c = cfg
        self.norm1 = RMSNorm(c.d_model, c.rms_eps, policy=c.policy)
        if spec.mixer in ("attn", "attn_cross"):
            self.attn = Attention(
                c.d_model, c.n_heads, c.n_kv_heads, c.head_dim,
                qkv_bias=c.qkv_bias, qk_norm=c.qk_norm, rope_theta=c.rope_theta,
                causal=c.causal, logit_softcap=c.logit_softcap, subln=c.subln,
                scores_dtype=c.attn_scores_dtype, impl=c.attn_impl,
                block_kv=c.block_kv, quant=c.quant, policy=c.policy)
        if spec.mixer in ("cross", "attn_cross"):
            self.xattn = Attention(
                c.d_model, c.n_heads, c.n_kv_heads, c.head_dim,
                qkv_bias=c.qkv_bias, qk_norm=c.qk_norm, use_rope=False,
                causal=False, cross=True, subln=c.subln,
                scores_dtype=c.attn_scores_dtype, impl=c.attn_impl,
                block_kv=c.block_kv, quant=c.quant, policy=c.policy)
            if spec.mixer == "attn_cross":
                self.norm_x = RMSNorm(c.d_model, c.rms_eps, policy=c.policy)
        if spec.mixer == "mamba":
            self.mamba = Mamba2Block(
                c.d_model, d_state=c.ssm_state, head_dim=c.ssm_head_dim,
                chunk=c.ssm_chunk, subln=True, quant=c.quant, policy=c.policy)
        if spec.ffn == "dense":
            self.mlp = GatedMLP(c.d_model, c.d_ff, c.activation, gated=c.mlp_gated,
                                subln=c.subln, quant=c.quant, policy=c.policy)
            self.norm2 = RMSNorm(c.d_model, c.rms_eps, policy=c.policy)
        elif spec.ffn == "moe":
            self.mlp = MoEMLP(c.d_model, c.d_ff, c.n_experts, c.top_k,
                              c.activation, capacity_factor=c.capacity_factor,
                              group_size=c.moe_group_size, subln=c.subln,
                              quant=c.quant, policy=c.policy)
            self.norm2 = RMSNorm(c.d_model, c.rms_eps, policy=c.policy)

    # -- params ---------------------------------------------------------------

    def init(self, key) -> Params:
        ks = split_keys(key, ["n1", "mix", "nx", "x", "n2", "ffn"])
        p: Params = {"norm1": self.norm1.init(ks["n1"])}
        if self.spec.mixer in ("attn", "attn_cross"):
            p["attn"] = self.attn.init(ks["mix"])
        if self.spec.mixer in ("cross", "attn_cross"):
            if self.spec.mixer == "attn_cross":
                p["norm_x"] = self.norm_x.init(ks["nx"])
            p["xattn"] = self.xattn.init(ks["x"])
        if self.spec.mixer == "mamba":
            p["mamba"] = self.mamba.init(ks["mix"])
        if self.spec.ffn != "none":
            p["norm2"] = self.norm2.init(ks["n2"])
            p["mlp"] = self.mlp.init(ks["ffn"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {"norm1": self.norm1.param_axes()}
        if self.spec.mixer in ("attn", "attn_cross"):
            ax["attn"] = self.attn.param_axes()
        if self.spec.mixer in ("cross", "attn_cross"):
            if self.spec.mixer == "attn_cross":
                ax["norm_x"] = self.norm_x.param_axes()
            ax["xattn"] = self.xattn.param_axes()
        if self.spec.mixer == "mamba":
            ax["mamba"] = self.mamba.param_axes()
        if self.spec.ffn != "none":
            ax["norm2"] = self.norm2.param_axes()
            ax["mlp"] = self.mlp.param_axes()
        return ax

    # -- forward ---------------------------------------------------------------

    def apply(self, p: Params, x: jax.Array, positions=None, memory=None,
              memory_mask=None, collect_states: bool = False
              ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """Returns (x, qkv_states|None, moe_aux_loss scalar)."""
        aux_states = None
        moe_loss = jnp.zeros((), jnp.float32)
        if self.spec.mixer in ("attn", "attn_cross"):
            h, aux, _ = self.attn.apply(p["attn"], self.norm1.apply(p["norm1"], x),
                                        positions=positions,
                                        collect_states=collect_states)
            x = x + h
            if collect_states and aux is not None:
                aux_states = jnp.stack([aux["q"], aux["k"], aux["v"]])
        if self.spec.mixer in ("cross", "attn_cross"):
            nname = "norm_x" if self.spec.mixer == "attn_cross" else "norm1"
            h, _, _ = self.xattn.apply(p["xattn"],
                                       self.norm_x.apply(p[nname], x) if self.spec.mixer == "attn_cross"
                                       else self.norm1.apply(p["norm1"], x),
                                       memory=memory, memory_mask=memory_mask)
            x = x + h
        if self.spec.mixer == "mamba":
            x = x + self.mamba.apply(p["mamba"], self.norm1.apply(p["norm1"], x))
        if self.spec.ffn == "dense":
            x = x + self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x))
        elif self.spec.ffn == "moe":
            h, aux = self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x))
            x = x + h
            moe_loss = moe_loss + aux["moe_aux_loss"]
        return x, aux_states, moe_loss

    # -- decode ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   memory: Optional[jax.Array] = None) -> Params:
        c: Params = {}
        if self.spec.mixer in ("attn", "attn_cross"):
            c["attn"] = self.attn.init_cache(batch, max_len, dtype)
        if self.spec.mixer in ("cross", "attn_cross"):
            # static projected encoder memory; filled by seed_cross_cache
            t = 1 if memory is None else memory.shape[1]
            c["xattn"] = self.xattn.init_cache(batch, t, dtype)
        if self.spec.mixer == "mamba":
            c["mamba"] = self.mamba.init_cache(batch, dtype)
        return c

    def cache_axes(self) -> Params:
        ax: Params = {}
        if self.spec.mixer in ("attn", "attn_cross"):
            ax["attn"] = Attention.cache_axes()
        if self.spec.mixer in ("cross", "attn_cross"):
            ax["xattn"] = Attention.cache_axes()
        if self.spec.mixer == "mamba":
            ax["mamba"] = Mamba2Block.cache_axes()
        return ax

    def decode(self, p: Params, x: jax.Array, cache: Params,
               cache_index: jax.Array,
               block_tables: Optional[jax.Array] = None,
               attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        new_cache: Params = {}
        if self.spec.mixer in ("attn", "attn_cross"):
            h, kv = self.attn.decode(p["attn"], self.norm1.apply(p["norm1"], x),
                                     cache["attn"], cache_index,
                                     block_tables=block_tables,
                                     attn_impl=attn_impl)
            x = x + h
            new_cache["attn"] = kv
        if self.spec.mixer in ("cross", "attn_cross"):
            nname = "norm_x" if self.spec.mixer == "attn_cross" else "norm1"
            h, kv = self.xattn.decode(p["xattn"], self.norm_x.apply(p[nname], x)
                                      if self.spec.mixer == "attn_cross"
                                      else self.norm1.apply(p["norm1"], x),
                                      cache["xattn"], cache_index)
            x = x + h
            new_cache["xattn"] = kv
        if self.spec.mixer == "mamba":
            h, sc = self.mamba.decode(p["mamba"], self.norm1.apply(p["norm1"], x),
                                      cache["mamba"])
            x = x + h
            new_cache["mamba"] = sc
        if self.spec.ffn == "dense":
            x = x + self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x))
        elif self.spec.ffn == "moe":
            h, _ = self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x),
                                  full_capacity=True)
            x = x + h
        return x, new_cache

    def decode_chunk(self, p: Params, x: jax.Array, cache: Params,
                     start: jax.Array, lens: jax.Array,
                     block_tables: jax.Array,
                     attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """Chunked-prefill step: x [B, T, D] advances up to T cache positions
        per row against the paged pools (nn/attention.py:Attention.
        decode_chunk).  Self-attention-only blocks — the engine routes models
        with SSM/cross caches through the sequential scan fallback instead."""
        if self.spec.mixer != "attn":
            raise ValueError(
                f"decode_chunk supports pure self-attention blocks; mixer "
                f"{self.spec.mixer!r} has no chunked paged path")
        h, kv = self.attn.decode_chunk(p["attn"],
                                       self.norm1.apply(p["norm1"], x),
                                       cache["attn"], start, lens,
                                       block_tables, attn_impl=attn_impl)
        x = x + h
        if self.spec.ffn == "dense":
            x = x + self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x))
        elif self.spec.ffn == "moe":
            # decode semantics (no token dropping), same as decode()
            h, _ = self.mlp.apply(p["mlp"], self.norm2.apply(p["norm2"], x),
                                  full_capacity=True)
            x = x + h
        return x, {"attn": kv}


@dataclasses.dataclass(frozen=True)
class Stack:
    """``repeats`` x ``pattern`` scanned transformer stack."""
    cfg: BlockConfig
    pattern: Tuple[LayerSpec, ...]
    repeats: int
    remat: bool = True
    remat_policy: str = "nothing"   # "nothing" | "dots" | "none"

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats

    def blocks(self):
        return [Block(self.cfg, s) for s in self.pattern]

    def layer_to_coords(self, layer: int) -> Tuple[int, int]:
        """global layer index -> (repeat, pattern position)."""
        return layer // len(self.pattern), layer % len(self.pattern)

    # -- params -------------------------------------------------------------------

    def init(self, key) -> Params:
        keys = jax.random.split(key, self.repeats)
        blocks = self.blocks()

        def init_rep(k):
            ks = jax.random.split(k, len(blocks))
            return {f"pos{i}": b.init(ks[i]) for i, b in enumerate(blocks)}

        return jax.vmap(init_rep)(keys)   # leaves stacked [repeats, ...]

    def param_axes(self) -> Params:
        blocks = self.blocks()
        ax = {f"pos{i}": b.param_axes() for i, b in enumerate(blocks)}
        return jax.tree_util.tree_map(lambda t: ("layers",) + t, ax,
                                      is_leaf=lambda t: isinstance(t, tuple))

    # -- forward --------------------------------------------------------------------

    def apply(self, p: Params, x: jax.Array, positions=None, memory=None,
              memory_mask=None, distill_layer: Optional[int] = None
              ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """Returns (x, qkv_states at distill_layer or None, total moe loss)."""
        blocks = self.blocks()
        collect = distill_layer is not None
        if collect:
            sel_rep, sel_pos = self.layer_to_coords(distill_layer)
            if blocks[sel_pos].spec.mixer not in ("attn", "attn_cross"):
                raise ValueError(
                    f"distill layer {distill_layer} is a "
                    f"{blocks[sel_pos].spec.mixer!r} layer; attention-relation "
                    "distillation needs an attention layer (DESIGN.md §4)")
        else:
            sel_rep = sel_pos = -1

        b, s, _ = x.shape
        c = self.cfg
        if collect:
            states0 = jnp.zeros((3, b, c.n_heads, s, c.head_dim), jnp.float32)
        else:
            states0 = jnp.zeros((), jnp.float32)

        from repro.distributed.sharding import constrain

        def body(carry, xs):
            h, states, moe = carry
            rep_params, rep_idx = xs
            for i, blk in enumerate(blocks):
                want = collect and i == sel_pos
                h, st, ml = blk.apply(rep_params[f"pos{i}"], h, positions=positions,
                                      memory=memory, memory_mask=memory_mask,
                                      collect_states=want)
                if want:
                    hit = (rep_idx == sel_rep)
                    states = jnp.where(hit, st.astype(jnp.float32), states)
                moe = moe + ml
            if c.seq_shard_activations:
                # Megatron-SP: the inter-layer residual (which the scan saves
                # for backward) lives sequence-sharded; per-layer gathers are
                # inserted by SPMD where full-seq mixing needs them.
                h = constrain(h, ("batch", "seq_sp", "act_embed"))
            return (h, states, moe), None

        if self.remat and self.remat_policy != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)

        (x, states, moe), _ = jax.lax.scan(
            body, (x, states0, jnp.zeros((), jnp.float32)),
            (p, jnp.arange(self.repeats)))
        return x, (states if collect else None), moe

    # -- decode -------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   memory: Optional[jax.Array] = None) -> Params:
        blocks = self.blocks()

        def one(_):
            return {f"pos{i}": b.init_cache(batch, max_len, dtype, memory)
                    for i, b in enumerate(blocks)}

        return jax.vmap(one)(jnp.arange(self.repeats))

    def cache_axes(self) -> Params:
        blocks = self.blocks()
        ax = {f"pos{i}": b.cache_axes() for i, b in enumerate(blocks)}
        return jax.tree_util.tree_map(lambda t: ("layers",) + t, ax,
                                      is_leaf=lambda t: isinstance(t, tuple))

    def decode(self, p: Params, x: jax.Array, cache: Params,
               cache_index: jax.Array,
               block_tables: Optional[jax.Array] = None,
               attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """cache_index: scalar or per-row [B] vector (mixed-depth batches);
        block_tables: int32 [B, L] selects the paged-pool cache layout (the
        table is scan-invariant — every repeat indexes its own pool leaf with
        the same logical->physical block mapping); attn_impl: "fused" runs
        the Pallas paged-decode kernel, "gather" the dense-window fallback
        (nn/attention.py:Attention.decode)."""
        blocks = self.blocks()

        def body(h, xs):
            rep_params, rep_cache = xs
            new_caches = {}
            for i, blk in enumerate(blocks):
                h, nc = blk.decode(rep_params[f"pos{i}"], h,
                                   rep_cache[f"pos{i}"], cache_index,
                                   block_tables=block_tables,
                                   attn_impl=attn_impl)
                new_caches[f"pos{i}"] = nc
            return h, new_caches

        x, new_cache = jax.lax.scan(body, x, (p, cache))
        return x, new_cache

    def decode_chunk(self, p: Params, x: jax.Array, cache: Params,
                     start: jax.Array, lens: jax.Array,
                     block_tables: jax.Array,
                     attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """Chunked-prefill step over the scanned stack: x [B, T, D], per-row
        chunk start/lens; block_tables int32 [B, L] (scan-invariant, every
        repeat indexes its own pool leaf with the same mapping); attn_impl as
        in decode()."""
        blocks = self.blocks()

        def body(h, xs):
            rep_params, rep_cache = xs
            new_caches = {}
            for i, blk in enumerate(blocks):
                h, nc = blk.decode_chunk(rep_params[f"pos{i}"], h,
                                         rep_cache[f"pos{i}"], start, lens,
                                         block_tables, attn_impl=attn_impl)
                new_caches[f"pos{i}"] = nc
            return h, new_caches

        x, new_cache = jax.lax.scan(body, x, (p, cache))
        return x, new_cache
