"""Top-k Mixture-of-Experts FFN with capacity-based one-hot dispatch.

Dispatch is grouped (tokens reshaped into groups of ``group_size``) so the
[G, S, E, C] dispatch/combine tensors stay bounded; under SPMD the group dim
follows the batch sharding so dispatch stays device-local while expert weights
are tensor-parallel over the `model` axis (expert dim when divisible, else the
expert-internal ffn dim -- see distributed/sharding.py).

Expert projections are BitLinear-quantized per expert (per-expert absmean
scale), matching DESIGN.md §4: the 1.58-bit technique covers expert FFNs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.bitlinear import SubLN
from repro.nn.layers import ACTIVATIONS
from repro.nn.module import DTypePolicy, DEFAULT_POLICY, fan_in_init, split_keys

Params = dict


@dataclasses.dataclass(frozen=True)
class MoEMLP:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    activation: str = "silu"
    capacity_factor: float = 1.25
    group_size: int = 2048
    subln: bool = False
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    quant: Q.QuantConfig = Q.FP
    policy: DTypePolicy = DEFAULT_POLICY

    def init(self, key) -> Params:
        ks = split_keys(key, ["router", "up", "gate", "down", "subln"])
        e, d, f = self.n_experts, self.d_model, self.d_ff
        pd = self.policy.param_dtype
        p: Params = {
            "router": {"w": fan_in_init(ks["router"], (d, e), jnp.float32)},
            "up": {"w": fan_in_init(ks["up"], (e, d, f), pd)},
            "gate": {"w": fan_in_init(ks["gate"], (e, d, f), pd)},
            "down": {"w": fan_in_init(ks["down"], (e, f, d), pd)},
        }
        if self.subln:
            p["subln"] = SubLN(f, axis_name="mlp", policy=self.policy).init(ks["subln"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {
            "router": {"w": ("embed", "expert_router")},
            "up": {"w": ("expert", "embed", "mlp")},
            "gate": {"w": ("expert", "embed", "mlp")},
            "down": {"w": ("expert", "mlp", "embed")},
        }
        if self.subln:
            ax["subln"] = {"scale": ("mlp",)}
        return ax

    # -- expert weight quantization (QAT) -------------------------------------

    def _maybe_quant(self, w: jax.Array) -> jax.Array:
        if self.quant.mode == "qat":
            return jax.vmap(lambda wi: Q.fake_quant_weight(
                wi.astype(jnp.float32), scheme=self.quant.scheme,
                block=self.quant.block))(w).astype(w.dtype)
        return w

    def apply(self, p: Params, x: jax.Array, full_capacity: bool = False
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """x: [B, S, D] -> (y, aux) with aux = {"moe_aux_loss"}.

        full_capacity=True (decode / eval): capacity = group size, so no
        token is ever dropped — routing becomes exact top-k."""
        cd = self.policy.compute_dtype
        b, s, d = x.shape
        tokens = b * s
        g = max(1, tokens // self.group_size) if tokens >= self.group_size else 1
        while tokens % g:
            g -= 1
        gs = tokens // g
        xg = x.reshape(g, gs, d)

        # Router (always fp32 — routing decisions are precision-critical).
        logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                            p["router"]["w"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, self.top_k)          # [g, gs, k]
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

        e = self.n_experts
        if full_capacity:
            cap = gs
        else:
            cap = int(max(1, round(gs * self.top_k / e * self.capacity_factor)))
            cap = min(cap, gs)

        # position of each (token, k) inside its expert's capacity buffer
        onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)       # [g, gs, k, e]
        flat = onehot.reshape(g, gs * self.top_k, e)
        pos = jnp.cumsum(flat, axis=1) - 1                       # [g, gs*k, e]
        pos = pos.reshape(g, gs, self.top_k, e)
        in_cap = (pos < cap) & (onehot > 0)
        combine = jnp.einsum(
            "gske,gskec->gsec",
            (top_w[..., None] * in_cap.astype(jnp.float32)),
            jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=jnp.float32)
            * in_cap[..., None].astype(jnp.float32),
        )                                                         # [g, gs, e, cap]
        dispatch = (combine > 0).astype(cd)

        # Dispatch -> expert FFN -> combine
        xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(cd))
        up = self._maybe_quant(p["up"]["w"]).astype(cd)
        gate = self._maybe_quant(p["gate"]["w"]).astype(cd)
        down = self._maybe_quant(p["down"]["w"]).astype(cd)
        act = ACTIVATIONS[self.activation]
        h = jnp.einsum("gecd,edf->gecf", xe, up) * act(
            jnp.einsum("gecd,edf->gecf", xe, gate))
        if self.subln:
            h = SubLN(self.d_ff, axis_name="mlp", policy=self.policy).apply(p["subln"], h)
        ye = jnp.einsum("gecf,efd->gecd", h, down)
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(cd), ye)

        # Switch-style load-balance loss + router z-loss
        density = jnp.mean(onehot.astype(jnp.float32), axis=(1, 2))      # [g, e]
        density_proxy = jnp.mean(probs, axis=1)                          # [g, e]
        lb = jnp.mean(jnp.sum(density * density_proxy, axis=-1)) * (e ** 2) / self.top_k
        z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
        aux = {"moe_aux_loss": self.aux_loss_weight * lb + self.router_z_weight * z}
        return y.reshape(b, s, d).astype(x.dtype), aux
