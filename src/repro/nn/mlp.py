"""Gated MLPs (SwiGLU / GeGLU) with SubLN before the down projection (Eq. 5)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from repro.core import quant as Q
from repro.core.bitlinear import BitLinear, SubLN
from repro.nn.layers import ACTIVATIONS
from repro.nn.module import DTypePolicy, DEFAULT_POLICY, split_keys

Params = dict


@dataclasses.dataclass(frozen=True)
class GatedMLP:
    d_model: int
    d_ff: int
    activation: str = "silu"       # "silu" -> SwiGLU, "gelu" -> GeGLU (gemma)
    gated: bool = True             # False -> plain 2-matrix MLP (whisper)
    subln: bool = False
    quant: Q.QuantConfig = Q.FP
    policy: DTypePolicy = DEFAULT_POLICY

    def _up(self):
        return BitLinear(self.d_model, self.d_ff, False, self.quant,
                         ("embed", "mlp"), self.policy)

    def _gate(self):
        return BitLinear(self.d_model, self.d_ff, False, self.quant,
                         ("embed", "mlp"), self.policy)

    def _down(self):
        return BitLinear(self.d_ff, self.d_model, False, self.quant,
                         ("mlp", "embed"), self.policy)

    def _subln(self):
        return SubLN(self.d_ff, axis_name="mlp", policy=self.policy)

    def init(self, key) -> Params:
        ks = split_keys(key, ["up", "gate", "down", "subln"])
        p: Params = {"up": self._up().init(ks["up"]),
                     "down": self._down().init(ks["down"])}
        if self.gated:
            p["gate"] = self._gate().init(ks["gate"])
        if self.subln:
            p["subln"] = self._subln().init(ks["subln"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {"up": self._up().param_axes(),
                      "down": self._down().param_axes()}
        if self.gated:
            ax["gate"] = self._gate().param_axes()
        if self.subln:
            ax["subln"] = self._subln().param_axes()
        return ax

    def apply(self, p: Params, x: jax.Array) -> jax.Array:
        act = ACTIVATIONS[self.activation]
        if self.gated:
            h = self._up().apply(p["up"], x) * act(self._gate().apply(p["gate"], x))
        else:
            h = act(self._up().apply(p["up"], x))
        if self.subln:
            h = self._subln().apply(p["subln"], h)
        return self._down().apply(p["down"], h)
