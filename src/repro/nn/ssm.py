"""Mamba2 (SSD — state-space duality) block.

Two equivalent forward paths:
  * ``ssd_chunked``   — blocked matmul form (MXU friendly; what the dry-run
                        lowers; mirrors the Pallas ``ssd_scan`` kernel tiling)
  * ``ssd_sequential``— lax.scan over time, the oracle used in tests.

Decode keeps an O(1) recurrent state [B, H, P, N] plus a (k-1)-deep conv tail,
which is what makes the long_500k shape tractable for SSM/hybrid archs.

BitLinear applies to in_proj / out_proj (DESIGN.md §4); the gated RMSNorm that
Mamba2 already places before out_proj coincides with the paper's SubLN
placement, so `subln=True` simply keeps it (and it is kept by default).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.bitlinear import BitLinear, SubLN
from repro.distributed.sharding import constrain
from repro.nn.layers import silu
from repro.nn.module import DTypePolicy, DEFAULT_POLICY, split_keys

Params = dict


@dataclasses.dataclass(frozen=True)
class Mamba2Block:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    subln: bool = True
    quant: Q.QuantConfig = Q.FP
    policy: DTypePolicy = DEFAULT_POLICY

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state

    @property
    def in_dim(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads

    def _in_proj(self):
        return BitLinear(self.d_model, self.in_dim, False, self.quant,
                         ("embed", "ssm_in"), self.policy)

    def _out_proj(self):
        return BitLinear(self.d_inner, self.d_model, False, self.quant,
                         ("ssm_inner", "embed"), self.policy)

    def init(self, key) -> Params:
        ks = split_keys(key, ["in", "out", "conv", "a", "dt", "norm"])
        pd = self.policy.param_dtype
        h = self.n_heads
        p: Params = {
            "in_proj": self._in_proj().init(ks["in"]),
            "out_proj": self._out_proj().init(ks["out"]),
            "conv_w": (jax.random.normal(ks["conv"], (self.conv_kernel, self.conv_dim),
                                         jnp.float32) * 0.1).astype(pd),
            "conv_b": jnp.zeros((self.conv_dim,), pd),
            # A in [-8, -0.5]-ish via A = -exp(A_log); init A_log ~ U[0, log 8]
            "A_log": jnp.log(1.0 + 7.0 * jax.random.uniform(ks["a"], (h,), jnp.float32)),
            "D": jnp.ones((h,), jnp.float32),
            "dt_bias": jnp.log(jnp.expm1(
                jnp.exp(jax.random.uniform(ks["dt"], (h,), jnp.float32)
                        * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3)))),
        }
        if self.subln:
            p["norm"] = SubLN(self.d_inner, axis_name="ssm_inner",
                              policy=self.policy).init(ks["norm"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {
            "in_proj": self._in_proj().param_axes(),
            "out_proj": self._out_proj().param_axes(),
            "conv_w": ("conv_k", "ssm_conv"),
            "conv_b": ("ssm_conv",),
            "A_log": ("ssm_heads",),
            "D": ("ssm_heads",),
            "dt_bias": ("ssm_heads",),
        }
        if self.subln:
            ax["norm"] = {"scale": ("ssm_inner",)}
        return ax

    # -- pieces ----------------------------------------------------------------

    def _split(self, zxbcdt: jax.Array):
        di, n, h = self.d_inner, self.d_state, self.n_heads
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di:di + self.conv_dim]
        dt = zxbcdt[..., di + self.conv_dim:]
        return z, xbc, dt

    def _conv(self, p: Params, xbc: jax.Array) -> jax.Array:
        """Causal depthwise conv over [B, S, conv_dim]."""
        k = self.conv_kernel
        w = p["conv_w"].astype(jnp.float32)                     # [k, c]
        pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
        return silu(out + p["conv_b"].astype(jnp.float32)).astype(xbc.dtype)

    def _gates(self, p: Params, dt_raw: jax.Array):
        """dt [B,S,H] -> (a = exp(dt*A) in (0,1), dt) both fp32."""
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
        a = jnp.exp(dt * (-jnp.exp(p["A_log"]))[None, None, :])
        return a, dt

    # -- full-sequence forward ---------------------------------------------------

    def apply(self, p: Params, u: jax.Array, sequential: bool = False) -> jax.Array:
        b, s, _ = u.shape
        di, n, h, pd = self.d_inner, self.d_state, self.n_heads, self.head_dim
        zxbcdt = self._in_proj().apply(p["in_proj"], u)
        z, xbc, dt_raw = self._split(zxbcdt)
        xbc = self._conv(p, xbc)
        # shard SSD compute (and its decay transients) across TP by heads
        x = constrain(xbc[..., :di].reshape(b, s, h, pd),
                      ("batch", "seq", "ssm_heads", "head_dim"))
        B = xbc[..., di:di + n]
        C = xbc[..., di + n:]
        a, dt = self._gates(p, dt_raw)
        a = constrain(a, ("batch", "seq", "ssm_heads"))

        fn = ssd_sequential if sequential else ssd_chunked
        y, _ = fn(x.astype(jnp.float32), a, dt, B.astype(jnp.float32),
                  C.astype(jnp.float32), chunk=self.chunk)
        y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
        y = y.reshape(b, s, di).astype(u.dtype)

        y = y * silu(z)
        if self.subln:
            y = SubLN(di, axis_name="ssm_inner", policy=self.policy).apply(p["norm"], y)
        return self._out_proj().apply(p["out_proj"], y)

    # -- decode (single token, recurrent state) ----------------------------------

    def init_cache(self, batch: int, dtype=jnp.float32) -> Params:
        return {
            "state": jnp.zeros((batch, self.n_heads, self.head_dim, self.d_state), jnp.float32),
            "conv": jnp.zeros((batch, self.conv_kernel - 1, self.conv_dim), dtype),
        }

    @staticmethod
    def cache_axes() -> Params:
        return {"state": ("batch", "ssm_heads", "head_dim", "ssm_state"),
                "conv": ("batch", "conv_k", "ssm_conv")}

    def decode(self, p: Params, u: jax.Array, cache: Params) -> Tuple[jax.Array, Params]:
        """u: [B, 1, D] -> (y [B, 1, D], cache)."""
        b = u.shape[0]
        di, n, h, pd = self.d_inner, self.d_state, self.n_heads, self.head_dim
        zxbcdt = self._in_proj().apply(p["in_proj"], u)
        z, xbc_new, dt_raw = self._split(zxbcdt)

        # conv over the cached tail + this token
        hist = jnp.concatenate([cache["conv"], xbc_new.astype(cache["conv"].dtype)], axis=1)
        w = p["conv_w"].astype(jnp.float32)
        xbc = silu(jnp.sum(hist.astype(jnp.float32) * w[None], axis=1, keepdims=True)
                   + p["conv_b"].astype(jnp.float32)[None, None]).astype(u.dtype)
        conv_cache = hist[:, 1:]

        x = xbc[..., :di].reshape(b, h, pd)
        B = xbc[:, 0, di:di + n].astype(jnp.float32)
        C = xbc[:, 0, di + n:].astype(jnp.float32)
        a, dt = self._gates(p, dt_raw)                          # [b,1,h]
        a1, dt1 = a[:, 0], dt[:, 0]

        state = cache["state"] * a1[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt1, x.astype(jnp.float32), B)
        y = jnp.einsum("bhpn,bn->bhp", state, C) + p["D"][None, :, None] * x.astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(u.dtype)

        y = y * silu(z)
        if self.subln:
            y = SubLN(di, axis_name="ssm_inner", policy=self.policy).apply(p["norm"], y)
        return (self._out_proj().apply(p["out_proj"], y),
                {"state": state, "conv": conv_cache})


# ---------------------------------------------------------------------------
# SSD cores (shared by Mamba2Block and the Pallas kernel's reference)
# ---------------------------------------------------------------------------

def ssd_sequential(x, a, dt, B, C, chunk: int = 0, init_state=None):
    """Oracle: scan over time.  x [b,s,h,p], a/dt [b,s,h], B/C [b,s,n].

    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    h0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None else init_state

    def step(hprev, t):
        xt, at, dtt, Bt, Ct = x[:, t], a[:, t], dt[:, t], B[:, t], C[:, t]
        hnew = hprev * at[:, :, None, None] + jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        yt = jnp.einsum("bhpn,bn->bhp", hnew, Ct)
        return hnew, yt

    hT, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), hT


def ssd_chunked(x, a, dt, B, C, chunk: int = 256, init_state=None):
    """Blocked SSD: intra-chunk attention-like matmul + inter-chunk recurrence."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q

    xc = x.reshape(b, nc, q, h, p)
    dc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)
    la = jnp.cumsum(jnp.log(jnp.maximum(a.reshape(b, nc, q, h), 1e-20)), axis=2)

    # intra-chunk (the "diagonal block"): M[q,k] = C_q.B_k exp(la_q - la_k) dt_k
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]            # [b,nc,q,k,h]
    causal = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp", cb, decay, dc, xc)

    # chunk summary states: S_c = sum_k B_k (dt_k exp(la_last - la_k)) x_k
    last = la[:, :, -1:, :]
    wk = dc * jnp.exp(last - la)                                  # [b,nc,q,h]
    S = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, wk, xc)

    # inter-chunk recurrence over chunk index
    a_chunk = jnp.exp(last[:, :, 0, :])                           # [b,nc,h]
    h0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None else init_state

    def step(hprev, c):
        hnew = hprev * a_chunk[:, c][:, :, None, None] + S[:, c]
        return hnew, hprev

    hT, hs = jax.lax.scan(step, h0, jnp.arange(nc))
    h_in = jnp.moveaxis(hs, 0, 1)                                 # [b,nc,h,p,n]

    # off-diagonal contribution: y_q += C_q . (exp(la_q) * h_in)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(la), h_in)
    y = (y_intra + y_off).reshape(b, s, h, p)
    return y, hT
