from repro.models.base import ModelConfig, get_config, list_configs, register
from repro.models.lm import CausalLM
from repro.models.encdec import EncDecLM


def build_model(cfg: ModelConfig):
    """Family-dispatching constructor used by launch/ and tests."""
    if cfg.n_encoder_layers > 0:
        return EncDecLM(cfg)
    return CausalLM(cfg)


__all__ = ["ModelConfig", "CausalLM", "EncDecLM", "build_model",
           "get_config", "list_configs", "register"]
