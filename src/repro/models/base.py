"""ModelConfig: one dataclass describing every assigned architecture.

The ``pattern``/``repeats`` pair drives the scanned Stack (nn/transformer.py),
so dense, MoE, SSM, hybrid, VLM-backbone and enc-dec families are all
instances of the same config type.  ``reduced()`` produces the tiny
same-family config used by per-arch smoke tests; full configs are only ever
lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import quant as Q
from repro.nn.module import DTypePolicy
from repro.nn.transformer import BlockConfig, LayerSpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 0
    activation: str = "silu"
    mlp_gated: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    embed_scale: bool = False       # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    # layer pattern; empty -> [attn+dense] * n_layers
    pattern: Tuple[LayerSpec, ...] = ()
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 2048
    # SSM
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # enc-dec (audio): encoder stack of n_encoder_layers, frame inputs
    n_encoder_layers: int = 0
    encoder_seq: int = 1500
    # vlm: cross-attn memory (precomputed image patch embeddings)
    num_image_tokens: int = 0
    # stage-1 refinement + quantization
    subln: bool = False
    quant: Q.QuantConfig = Q.FP
    # perf knobs (§Perf; defaults = paper-faithful naive baseline)
    attn_scores_dtype: str = "float32"
    attn_impl: str = "dense"
    block_kv: int = 1024
    seq_shard_activations: bool = False
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"
    max_seq: int = 4096
    # pad the embedding/logit vocab dim so it shards over the TP axis
    # (standard production practice; 1 = exact vocab, launch sets 512).
    vocab_pad_multiple: int = 1

    # -- derived -------------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    def resolved_pattern(self) -> Tuple[LayerSpec, ...]:
        if self.pattern:
            return self.pattern
        return (LayerSpec("attn", "moe" if self.n_experts else "dense"),)

    @property
    def repeats(self) -> int:
        p = self.resolved_pattern()
        assert self.n_layers % len(p) == 0, (self.name, self.n_layers, len(p))
        return self.n_layers // len(p)

    def policy(self) -> DTypePolicy:
        return DTypePolicy(param_dtype=jnp.dtype(self.param_dtype),
                           compute_dtype=jnp.dtype(self.compute_dtype))

    def block_config(self) -> BlockConfig:
        return BlockConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim, d_ff=self.d_ff,
            activation=self.activation, mlp_gated=self.mlp_gated,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta, logit_softcap=self.logit_softcap,
            n_experts=self.n_experts, top_k=self.top_k,
            moe_group_size=self.moe_group_size,
            capacity_factor=self.capacity_factor,
            ssm_state=self.ssm_state, ssm_head_dim=self.ssm_head_dim,
            ssm_chunk=self.ssm_chunk, subln=self.subln, quant=self.quant,
            attn_scores_dtype=self.attn_scores_dtype,
            attn_impl=self.attn_impl,
            block_kv=self.block_kv,
            seq_shard_activations=self.seq_shard_activations,
            policy=self.policy())

    # -- config surgery ---------------------------------------------------------

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_quant(self, quant: Q.QuantConfig) -> "ModelConfig":
        """Teacher -> student conversion at the config level (stage 1 adds
        SubLN whenever the model is quantized, per Eqs. 4-5)."""
        return self.replace(quant=quant, subln=quant.is_quantized or self.subln)

    def reduced(self, layers: Optional[int] = None) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        p = self.resolved_pattern()
        reps = max(1, min(2, self.repeats))
        kw = dict(
            n_layers=len(p) * reps,
            d_model=128,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads,
                                             4 * self.n_kv_heads // max(self.n_heads, 1)) or 1),
            head_dim=32,
            d_ff=(256 if self.d_ff else 0),
            vocab=288,  # >= ByteTokenizer.vocab_size (268), 16-divisible
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_group_size=64,
            ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
            n_encoder_layers=(len(p) and self.n_encoder_layers and 2) or 0,
            encoder_seq=16 if self.n_encoder_layers else self.encoder_seq,
            num_image_tokens=8 if self.num_image_tokens else 0,
            max_seq=64,
            param_dtype="float32", compute_dtype="float32",
            remat=False,
        )
        if layers is not None:
            kw["n_layers"] = layers
        return self.replace(**kw)

    # -- analytics ----------------------------------------------------------------

    def param_count(self) -> int:
        """Analytic parameter count (matches init, used for roofline 6ND)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        qd = self.n_heads * self.head_dim
        kvd = self.n_kv_heads * self.head_dim
        attn = d * qd + 2 * d * kvd + qd * d
        if self.qkv_bias:
            attn += qd + 2 * kvd
        dense_ffn = d * f * (3 if self.mlp_gated else 2)
        moe_ffn = self.n_experts * d * f * 3 + d * self.n_experts
        d_inner = 2 * d
        nheads_ssm = d_inner // self.ssm_head_dim
        ssm = (d * (2 * d_inner + 2 * self.ssm_state + nheads_ssm)
               + d_inner * d + 4 * (d_inner + 2 * self.ssm_state)
               + 3 * nheads_ssm + d_inner)
        total = 0
        pat = self.resolved_pattern()
        reps = self.repeats
        for spec in pat:
            if spec.mixer in ("attn", "attn_cross"):
                total += attn
            if spec.mixer in ("cross", "attn_cross"):
                total += attn
            if spec.mixer == "mamba":
                total += ssm
            if spec.ffn == "dense":
                total += dense_ffn
            elif spec.ffn == "moe":
                total += moe_ffn
            total += 2 * d  # norms (approx)
        total *= reps
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + dense_ffn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D roofline)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d, f = self.d_model, self.d_ff
        pat, reps = self.resolved_pattern(), self.repeats
        n_moe = sum(1 for s in pat if s.ffn == "moe") * reps
        inactive = n_moe * (self.n_experts - self.top_k) * d * f * 3
        return full - inactive


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
