"""Encoder-decoder LM (whisper-medium backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, T_enc, d_model]; a learned linear
projection + sinusoidal positions stand in for the mel conv stack.  The
decoder is a causal stack whose every layer carries self- and cross-attention
(pattern ``attn_cross``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.lm import CausalLM
from repro.nn.layers import Embedding, RMSNorm
from repro.nn.module import fan_in_init, split_keys
from repro.nn.transformer import LayerSpec, Stack

Params = dict


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig

    def __post_init__(self):
        assert self.cfg.n_encoder_layers > 0

    def _encoder(self) -> Stack:
        c = self.cfg
        enc_bc = dataclasses.replace(c.block_config(), causal=False)
        return Stack(enc_bc, (LayerSpec("attn", "dense"),), c.n_encoder_layers,
                     remat=c.remat, remat_policy=c.remat_policy)

    def _decoder(self) -> CausalLM:
        c = self.cfg.replace(pattern=(LayerSpec("attn_cross", "dense"),))
        return CausalLM(c)

    def init(self, key) -> Params:
        c = self.cfg
        ks = split_keys(key, ["front", "enc", "enc_norm", "dec"])
        return {
            "frontend": {"w": fan_in_init(ks["front"], (c.d_model, c.d_model),
                                          c.policy().param_dtype)},
            "encoder": self._encoder().init(ks["enc"]),
            "enc_norm": RMSNorm(c.d_model, policy=c.policy()).init(ks["enc_norm"]),
            "decoder": self._decoder().init(ks["dec"]),
        }

    def param_axes(self) -> Params:
        c = self.cfg
        return {
            "frontend": {"w": ("embed", "embed_out")},
            "encoder": self._encoder().param_axes(),
            "enc_norm": RMSNorm(c.d_model).param_axes(),
            "decoder": self._decoder().param_axes(),
        }

    # -- forward ------------------------------------------------------------------

    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        """frames [B, T_enc, d_model] (precomputed; conv frontend stubbed)."""
        c = self.cfg
        cd = c.policy().compute_dtype
        x = jnp.matmul(frames.astype(cd), p["frontend"]["w"].astype(cd))
        x = x + sinusoidal_positions(x.shape[1], c.d_model).astype(cd)[None]
        x, _, _ = self._encoder().apply(p["encoder"], x)
        return RMSNorm(c.d_model, policy=c.policy()).apply(p["enc_norm"], x)

    def apply(self, p: Params, frames: jax.Array, tokens: jax.Array,
              distill_layer: Optional[int] = None
              ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        memory = self.encode(p, frames)
        return self._decoder().apply(p["decoder"], tokens, memory=memory,
                                     distill_layer=distill_layer)

    # -- decode ---------------------------------------------------------------------

    def init_cache(self, p: Params, batch: int, max_len: int,
                   dtype=jnp.bfloat16, frames: Optional[jax.Array] = None) -> Params:
        memory = None if frames is None else self.encode(p, frames)
        return self._decoder().init_cache(p["decoder"], batch, max_len, dtype,
                                          memory=memory)

    def cache_axes(self) -> Params:
        return self._decoder().cache_axes()

    def decode_step(self, p: Params, token: jax.Array, cache: Params,
                    cache_index: jax.Array,
                    block_tables: Optional[jax.Array] = None,
                    attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        return self._decoder().decode_step(p["decoder"], token, cache,
                                           cache_index,
                                           block_tables=block_tables,
                                           attn_impl=attn_impl)
