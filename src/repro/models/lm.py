"""CausalLM: decoder-only language model over the scanned Stack.

Covers dense / GQA / MoE / SSM / hybrid / VLM-backbone families.  The VLM
variant consumes ``memory`` (precomputed image patch embeddings, the modality
frontend stub) through its cross-attention layers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.bitlinear import BitLinear
from repro.distributed.sharding import constrain
from repro.models.base import ModelConfig
from repro.nn.layers import Embedding, RMSNorm
from repro.nn.module import split_keys
from repro.nn.transformer import Stack

Params = dict


@dataclasses.dataclass(frozen=True)
class CausalLM:
    cfg: ModelConfig

    # -- submodules -------------------------------------------------------------

    def _stack(self) -> Stack:
        c = self.cfg
        return Stack(c.block_config(), c.resolved_pattern(), c.repeats,
                     remat=c.remat, remat_policy=c.remat_policy)

    def _embed(self) -> Embedding:
        return Embedding(self.cfg.padded_vocab, self.cfg.d_model, self.cfg.policy())

    def _final_norm(self) -> RMSNorm:
        return RMSNorm(self.cfg.d_model, policy=self.cfg.policy())

    def _head(self) -> Optional[BitLinear]:
        if self.cfg.tie_embeddings:
            return None
        hq = self.cfg.quant if self.cfg.quant.quantize_lm_head else Q.FP
        return BitLinear(self.cfg.d_model, self.cfg.padded_vocab, False, hq,
                         ("embed", "vocab"), self.cfg.policy())

    # -- params -------------------------------------------------------------------

    def init(self, key) -> Params:
        ks = split_keys(key, ["embed", "stack", "norm", "head"])
        p: Params = {
            "embed": self._embed().init(ks["embed"]),
            "stack": self._stack().init(ks["stack"]),
            "final_norm": self._final_norm().init(ks["norm"]),
        }
        head = self._head()
        if head is not None:
            p["head"] = head.init(ks["head"])
        return p

    def param_axes(self) -> Params:
        ax: Params = {
            "embed": self._embed().param_axes(),
            "stack": self._stack().param_axes(),
            "final_norm": self._final_norm().param_axes(),
        }
        head = self._head()
        if head is not None:
            ax["head"] = head.param_axes()
        return ax

    # -- forward --------------------------------------------------------------------

    def apply(self, p: Params, tokens: jax.Array,
              positions: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              memory_mask: Optional[jax.Array] = None,
              distill_layer: Optional[int] = None,
              ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """tokens [B, S] -> (fp32 logits [B, S, V], qkv_states|None, moe_loss)."""
        c = self.cfg
        x = self._embed().apply(p["embed"], tokens)
        x = constrain(x, ("batch", "seq", "act_embed"))
        if c.embed_scale:
            x = x * jnp.sqrt(c.d_model).astype(x.dtype)
        if memory is not None:
            memory = memory.astype(x.dtype)
        x, states, moe_loss = self._stack().apply(
            p["stack"], x, positions=positions, memory=memory,
            memory_mask=memory_mask, distill_layer=distill_layer)
        x = self._final_norm().apply(p["final_norm"], x)
        logits = constrain(self._logits(p, x), ("batch", "seq", "vocab"))
        return logits, states, moe_loss

    def _logits(self, p: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            logits = self._embed().attend(p["embed"], x)
        else:
            logits = self._head().apply(p["head"], x)
        vp, v = self.cfg.padded_vocab, self.cfg.vocab
        if vp != v:
            # padded vocab rows never win the softmax / argmax
            mask = (jnp.arange(vp) < v)
            logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
        return logits

    # -- decode ----------------------------------------------------------------------

    def init_cache(self, p: Params, batch: int, max_len: int,
                   dtype=jnp.bfloat16, memory: Optional[jax.Array] = None) -> Params:
        cache = self._stack().init_cache(batch, max_len, dtype, memory)
        if memory is not None:
            cache = self._seed_cross(p, cache, memory.astype(dtype))
        return cache

    def cache_axes(self) -> Params:
        return self._stack().cache_axes()

    def _seed_cross(self, p: Params, cache: Params, memory: jax.Array) -> Params:
        """Project encoder/image memory into every cross-attn cache slot."""
        stack = self._stack()
        blocks = stack.blocks()
        for i, blk in enumerate(blocks):
            if blk.spec.mixer not in ("cross", "attn_cross"):
                continue
            xattn = blk.xattn

            def project(rep_p):
                k, v = xattn._project_kv(rep_p[f"pos{i}"]["xattn"], memory, None)
                # cache layout [B, Hkv, T, Dh]
                return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

            kv = jax.vmap(project)(p["stack"])  # [R, B, T, Hkv, Dh]
            cache = dict(cache)
            ca = dict(cache)
            ca[f"pos{i}"] = {**cache[f"pos{i}"], "xattn": jax.tree_util.tree_map(
                lambda a, b: a.astype(b.dtype), kv, cache[f"pos{i}"]["xattn"])}
            cache = ca
        return cache

    def prefill(self, p: Params, tokens: jax.Array, cache: Params,
                memory: Optional[jax.Array] = None) -> Tuple[jax.Array, Params]:
        """Run the full prompt, fill caches, return last-token logits.

        Implemented as a full forward whose per-layer K/V are written into the
        cache (self-attn layers); SSM layers rebuild their state via a final
        sequential pass — used by serving, not by the dry-run prefill cell
        (which lowers the plain forward).
        """
        logits, _, _ = self.apply(p, tokens, memory=memory)
        # Fill caches by replaying projections per layer (cheap vs attention).
        cache = self._fill_cache_from_prompt(p, tokens, cache, memory)
        return logits[:, -1], cache

    def _fill_cache_from_prompt(self, p, tokens, cache, memory):
        # A second pass that runs decode semantics over the prompt would be
        # O(S) sequential; instead we recompute per-layer inputs via the full
        # forward with collectors.  For framework simplicity serving prefills
        # through the engine's chunked step (serving/engine.py, driven by the
        # continuous-batching scheduler — decode_chunk on paged stacks, a
        # masked decode-step scan otherwise); here we return the cache
        # unchanged for API completeness.
        return cache

    def decode_step(self, p: Params, token: jax.Array, cache: Params,
                    cache_index: jax.Array,
                    block_tables: Optional[jax.Array] = None,
                    attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """token [B] int32 -> (fp32 logits [B, V], new cache).

        ``cache_index`` may be a scalar (uniform-depth batch) or an int32 [B]
        vector of per-row cache positions — the continuous-batching scheduler
        (serving/scheduler.py) keeps rows at different prompt/generation
        depths in one decode batch.  ``block_tables`` (int32 [B, L]) selects
        the paged KV layout: the cache is a shared block pool per layer and
        row ``b``'s position ``i`` lives in pool block
        ``block_tables[b, i // block_size]`` (serving/paged.py).
        ``attn_impl`` picks the paged attention path: ``"fused"`` streams KV
        blocks through the Pallas kernel (kernels/paged_attention),
        ``"gather"`` materializes the dense table window (the fallback;
        ignored when ``block_tables`` is None)."""
        c = self.cfg
        x = self._embed().apply(p["embed"], token[:, None])
        if c.embed_scale:
            x = x * jnp.sqrt(c.d_model).astype(x.dtype)
        x, cache = self._stack().decode(p["stack"], x, cache, cache_index,
                                        block_tables=block_tables,
                                        attn_impl=attn_impl)
        x = self._final_norm().apply(p["final_norm"], x)
        return self._logits(p, x)[:, 0], cache

    def decode_chunk(self, p: Params, tokens: jax.Array, cache: Params,
                     start: jax.Array, lens: jax.Array,
                     block_tables: jax.Array,
                     attn_impl: str = "gather") -> Tuple[jax.Array, Params]:
        """Chunked prefill/decode: tokens [B, T] int32 -> (fp32 logits
        [B, T, V], new cache).  Token ``j`` of row ``b`` is written at paged
        cache position ``start[b] + j`` (valid iff ``j < lens[b]``) and
        attends positions ``<= start[b] + j`` — the serving engine's fused
        step runs prefilling rows (chunks of the prompt) and decoding rows
        (``lens == 1``, the last sampled token) through one call.  Requires
        the paged cache and a pure self-attention stack; models with SSM or
        cross-attention caches take the engine's sequential scan fallback."""
        c = self.cfg
        x = self._embed().apply(p["embed"], tokens)
        if c.embed_scale:
            x = x * jnp.sqrt(c.d_model).astype(x.dtype)
        x, cache = self._stack().decode_chunk(p["stack"], x, cache, start,
                                              lens, block_tables,
                                              attn_impl=attn_impl)
        x = self._final_norm().apply(p["final_norm"], x)
        return self._logits(p, x), cache
