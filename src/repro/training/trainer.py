"""Train-step factories: plain CE (stages 1-2 / SFT baselines) and the
distillation step (stage 3), with gradient accumulation.

Steps are pure jittable functions ``(state, batch [, teacher_params]) ->
(state, metrics)`` — single-device in tests, pjit-wrapped with shardings by
launch/train.py.  Gradient all-reduction across data shards is implicit in
SPMD (batch is sharded, grads come out replicated/sharded per param specs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.distill import DistillConfig, bitdistill_loss, softmax_cross_entropy
from repro.models.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.training.optimizer import AdamW, AdamWState

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt_state: AdamWState
    step: jax.Array


def init_train_state(params: Params, optimizer: AdamW) -> TrainState:
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# forward adapters
# ---------------------------------------------------------------------------

def forward(model, params, batch: Dict[str, jax.Array],
            distill_layer: Optional[int] = None):
    """-> (logits, qkv_states|None, moe_loss) for any model family."""
    if isinstance(model, EncDecLM):
        return model.apply(params, batch["frames"], batch["tokens"],
                           distill_layer=distill_layer)
    return model.apply(params, batch["tokens"],
                       memory=batch.get("image_embeds"),
                       distill_layer=distill_layer)


def default_distill_layer(cfg: ModelConfig) -> int:
    """Fig. 3b: late layers distill best -> last attention-bearing layer."""
    pat = cfg.resolved_pattern()
    last = None
    for li in range(cfg.n_layers - 1, -1, -1):
        if pat[li % len(pat)].mixer in ("attn", "attn_cross"):
            last = li
            break
    if last is None:
        raise ValueError(f"{cfg.name}: no attention layers; AD inapplicable")
    return last


def _microbatches(batch: Dict[str, jax.Array], accum: int) -> Dict[str, jax.Array]:
    def reshape(x):
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
    return {k: reshape(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# plain CE step (stage 2 continual pre-training, SFT baselines)
# ---------------------------------------------------------------------------

def make_train_step(model, optimizer: AdamW, lr_fn: Callable,
                    accum: int = 1,
                    grad_constraint: Optional[Callable] = None) -> Callable:
    """grad_constraint: optional fn(grads)->grads placing sharding
    constraints so SPMD reduce-scatters gradients straight to the parameter
    shards (ZeRO-2/3 semantics) instead of all-reducing them."""
    def loss_fn(params, mb):
        logits, _, moe = forward(model, params, mb)
        ce = softmax_cross_entropy(logits, mb["labels"], mb.get("loss_mask"))
        return ce + moe, {"loss_ce": ce, "loss_moe": moe}

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            mbs = _microbatches(batch, accum)

            def body(carry, mb):
                gacc, lacc = carry
                (l, m), g = grad_fn(state.params, mb)
                return (jax.tree_util.tree_map(jnp.add, gacc, g), lacc + l), m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), ms = jax.lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree_util.tree_map(jnp.mean, ms)
        if grad_constraint is not None:
            grads = grad_constraint(grads)
        lr = lr_fn(state.step)
        params, opt_state, opt_metrics = optimizer.update(
            grads, state.opt_state, state.params, lr)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


# ---------------------------------------------------------------------------
# stage-3 distillation step
# ---------------------------------------------------------------------------

def make_distill_step(student_model, teacher_model, optimizer: AdamW,
                      lr_fn: Callable, dcfg: DistillConfig,
                      accum: int = 1) -> Callable:
    """step(state, batch, teacher_params) — teacher frozen, student QAT."""
    want_states = dcfg.use_ad
    dl = dcfg.distill_layer

    def teacher_fwd(tparams, mb):
        logits, states, _ = forward(teacher_model, tparams, mb,
                                    distill_layer=dl if want_states else None)
        return jax.lax.stop_gradient(logits), (
            None if states is None else jax.lax.stop_gradient(states))

    def loss_fn(params, mb, t_logits, t_states):
        logits, states, moe = forward(student_model, params, mb,
                                      distill_layer=dl if want_states else None)
        loss, metrics = bitdistill_loss(
            logits, t_logits if dcfg.use_ld else None,
            states, t_states, mb["labels"], mb.get("loss_mask"), dcfg)
        return loss + moe, metrics

    def step(state: TrainState, batch, teacher_params):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        if accum == 1:
            t_logits, t_states = teacher_fwd(teacher_params, batch)
            (loss, metrics), grads = grad_fn(state.params, batch, t_logits, t_states)
        else:
            mbs = _microbatches(batch, accum)

            def body(carry, mb):
                gacc, lacc = carry
                t_logits, t_states = teacher_fwd(teacher_params, mb)
                (l, m), g = grad_fn(state.params, mb, t_logits, t_states)
                return (jax.tree_util.tree_map(jnp.add, gacc, g), lacc + l), m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), ms = jax.lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree_util.tree_map(jnp.mean, ms)
        lr = lr_fn(state.step)
        params, opt_state, opt_metrics = optimizer.update(
            grads, state.opt_state, state.params, lr)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


# ---------------------------------------------------------------------------
# eval steps
# ---------------------------------------------------------------------------

def make_eval_loss(model) -> Callable:
    @jax.jit
    def eval_step(params, batch):
        logits, _, _ = forward(model, params, batch)
        return softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return eval_step


def make_eval_classify(model, label_base: int, n_labels: int) -> Callable:
    """Accuracy of the answer-position label-token argmax."""
    @jax.jit
    def eval_step(params, batch):
        logits, _, _ = forward(model, params, batch)          # [B, S, V]
        pos = batch["answer_pos"]                             # [B]
        rows = jnp.take_along_axis(
            logits, pos[:, None, None], axis=1)[:, 0]         # [B, V]
        label_logits = jax.lax.dynamic_slice_in_dim(rows, label_base, n_labels, axis=1)
        pred = jnp.argmax(label_logits, axis=-1)
        return jnp.mean((pred == batch["class_label"]).astype(jnp.float32))
    return eval_step
