"""AdamW in pure JAX, with optional Dettmers-style blockwise 8-bit moments.

The 8-bit state path ([DLSZ21], the paper's own Table-4 citation) stores both
Adam moments as int8 codes with a per-block (default 256 elems) absmax scale:
  m ≈ code/127 * scale.
That cuts optimizer HBM from 8 to ~2.06 bytes/param, which is what lets the
123B/314B/398B dry-run configs fit 16 GB/chip (DESIGN.md §8).

All update math is fp32; codes are decoded/re-encoded inside the update, so
the pjit-sharded state keeps the parameter's sharding (codes inherit the param
layout; scales shard on the same leading axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"       # "float32" | "int8_blockwise"
    # weight decay applies only to leaves with ndim >= 2 (matrices), the
    # standard transformer recipe (norm scales / biases excluded).


class Moment8(NamedTuple):
    """Blockwise int8 moment.  Blocks run along the LAST axis so the code
    keeps the parameter's shape (and therefore its sharding spec) and the
    scale shards on the parameter's leading axes:
      code  [..., N]            int8
      scale [..., ceil(N/256)]  fp32
    Only ndim>=2 leaves are quantized (norm scales / biases stay fp32)."""
    code: jax.Array
    scale: jax.Array


def _use_q8(p) -> bool:
    return getattr(p, "ndim", 0) >= 2


def _q8_encode(x: jax.Array) -> Moment8:
    *lead, n = x.shape
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    xb = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)]) if pad else x
    xb = xb.reshape(*lead, nb, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) + 1e-12
    code = jnp.clip(jnp.round(xb / scale[..., None] * 127.0), -127, 127
                    ).astype(jnp.int8).reshape(*lead, nb * BLOCK)
    return Moment8(code[..., :n], scale.astype(jnp.float32))


def _q8_decode(m: Moment8, shape) -> jax.Array:
    *lead, n = shape
    nb = m.scale.shape[-1]
    pad = nb * BLOCK - n
    code = jnp.pad(m.code, [(0, 0)] * len(lead) + [(0, pad)]) if pad else m.code
    xb = code.reshape(*lead, nb, BLOCK).astype(jnp.float32) / 127.0
    return (xb * m.scale[..., None]).reshape(*lead, nb * BLOCK)[..., :n]


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg

    def init(self, params: Params) -> AdamWState:
        if self.cfg.state_dtype == "int8_blockwise":
            zeros = lambda p: (_q8_encode(jnp.zeros(p.shape, jnp.float32))
                               if _use_q8(p) else jnp.zeros(p.shape, jnp.float32))
        else:
            zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        m = jax.tree_util.tree_map(zeros, params)
        v = jax.tree_util.tree_map(zeros, params)
        return AdamWState(jnp.zeros((), jnp.int32), m, v)

    def update(self, grads: Params, state: AdamWState, params: Params,
               lr: jax.Array) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
        cfg = self.cfg
        step = state.step + 1

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
            if cfg.grad_clip > 0 else jnp.float32(1.0)

        q8 = cfg.state_dtype == "int8_blockwise"
        is_leaf = (lambda x: isinstance(x, Moment8)) if q8 else None

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            use8 = q8 and _use_q8(p)
            mf = _q8_decode(m, p.shape) if use8 else m
            vf = _q8_decode(v, p.shape) if use8 else v
            mf = cfg.b1 * mf + (1 - cfg.b1) * g
            vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
            mhat = mf / (1 - cfg.b1 ** step.astype(jnp.float32))
            vhat = vf / (1 - cfg.b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay > 0 and p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, (_q8_encode(mf) if use8 else mf), (_q8_encode(vf) if use8 else vf)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = jax.tree_util.tree_leaves(state.m, is_leaf=is_leaf)
        flat_v = jax.tree_util.tree_leaves(state.v, is_leaf=is_leaf)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step, new_m, new_v), metrics

    def state_bytes_per_param(self) -> float:
        return 2.0 + 8.0 / BLOCK if self.cfg.state_dtype == "int8_blockwise" else 8.0

    def state_axes(self, param_axes: Params) -> "AdamWState":
        """Logical-axes tree matching init(params) (for the sharding plan)."""
        q8 = self.cfg.state_dtype == "int8_blockwise"
        is_axes = lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t)

        def map_leaf(a):
            if q8 and len(a) >= 2:
                return Moment8(code=a, scale=a[:-1] + (None,))
            return a

        m = jax.tree_util.tree_map(map_leaf, param_axes, is_leaf=is_axes)
        return AdamWState(step=(), m=m, v=m)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
