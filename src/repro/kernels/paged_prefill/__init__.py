"""Fused paged-prefill chunk attention: T prompt tokens per step against the
block pools.

Grid (B, Hkv, L) on the same blocking template as paged_attention's decode
kernel: the logical-block dim is innermost, an online-softmax (m, z, acc)
carry for all T*g query rows lives in VMEM scratch across a row's blocks, and
per-row chunk starts/lengths plus the block table arrive as scalar-prefetch
operands that drive the pool BlockSpec index maps.  Resident KV (including
trie-shared prefix blocks — no gather-into-contiguous-cache seeding step) is
streamed once per (row, kv-head); the chunk's own K/V never round-trips
through HBM: its causal T x T scores fold into the carry at the last touched
block and the chunk KV is scatter-written into the row's pool blocks through
aliased pool outputs.  KV bytes read per chunk step are O(tokens resident),
not O(B * table_width * block_size).  See kernel.py for the full scheme.
"""
from repro.kernels.paged_prefill import kernel, ops, ref  # noqa: F401
