"""Fused paged-prefill chunk kernel (serving admission hot path).

One chunked-prefill step of paged attention: each row advances a chunk of up
to T prompt tokens at once (positions ``start[b] .. start[b] + lens[b] - 1``)
against the shared block pools, instead of a token-at-a-time ``lax.scan`` of
decode steps on a private contiguous cache.  Decode rows are the ``lens == 1``
special case (the chunk is the row's last sampled token), so one grid scheme
serves Sarathi-style piggybacked steps that mix prefilling and decoding rows.

Grid / blocking scheme
----------------------
Grid ``(B, Hkv, L)`` with the logical-block dimension innermost, reusing
paged_attention's template: the fp32 (m, z, acc) carry for all ``T * g``
query rows persists in VMEM scratch across a row's blocks.  ``start``,
``lens``, and ``block_tables`` ride in as scalar-prefetch operands; the K/V
pool BlockSpec index map reads ``bt[b, min(i, c1)]`` (``c1`` = the last block
the row's chunk touches) so the pipeline streams each resident block exactly
once and rows shallower than the table width cost nothing past their last
block — KV bytes read per chunk step are ``O(tokens resident)``, not
``O(B * L * bs)``.

In-kernel semantics (mirrors nn/attention.py's chunk-gather fallback):

  * resident positions ``p < start[b]`` are attended by every chunk token;
    garbage beyond them (stale partial-block slots, trash-block contents for
    parked idle rows) is masked by zeroing its softmax weight.  Trie-shared
    prefix blocks are read in place — the prefix-cache seeding gather of the
    retired batch-of-one prefill path does not exist here;
  * the chunk attends itself causally (token ``j`` sees tokens ``<= j``)
    straight from the VMEM chunk operands at the row's last touched block —
    the chunk's K/V is folded into the carry without an HBM round-trip;
  * the chunk's K/V is scatter-written into the row's pool blocks covering
    ``[start, start + lens)`` via pool outputs aliased onto the pool inputs:
    each touched block is rewritten with the chunk rows spliced in (a one-hot
    ``[bs, T]`` matmul — no dynamic gather), every other block is untouched,
    and pad rows ``j >= lens[b]`` are never written;
  * idle rows (table all trash, parked start) stream the trash block and
    produce finite garbage the caller discards — no occupancy branch, the
    same contract as the decode kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(start_ref, lens_ref, bt_ref, q_ref, kc_ref, vc_ref, kp_ref, vp_ref,
            o_ref, ko_ref, vo_ref, m_ref, z_ref, acc_ref,
            *, bs: int, n_log: int, t: int, g: int, scale: float,
            softcap: float):
    b, i = pl.program_id(0), pl.program_id(2)
    start = start_ref[b]
    ln = lens_ref[b]
    lr = (start - 1) // bs                     # last resident block (-1: none)
    c0 = jnp.minimum(start // bs, n_log - 1)   # first block the chunk writes
    c1 = jnp.minimum((start + ln - 1) // bs, n_log - 1)   # last block touched

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        z_ref[...] = jnp.zeros_like(z_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # [t*g, Dh], row r = j*g + gi

    @pl.when(i <= lr)
    def _resident():
        kb = kp_ref[0, 0].astype(jnp.float32)  # [bs, Dh]
        vb = vp_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (t * g, bs), 1)
        valid = pos < start                    # resident prefix only
        # mask by zeroing the exp term (not by NEG_INF scores): a block with
        # no stored tokens must contribute exactly nothing to the carry even
        # while m is still at its NEG_INF init (exp(NEG-NEG)=1 would leak)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        c = jnp.exp(m_ref[...] - m_new)
        p = jnp.exp(s - m_new) * valid
        m_ref[...] = m_new
        z_ref[...] = z_ref[...] * c + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * c + jax.lax.dot(
            p, vb, preferred_element_type=jnp.float32)

    @pl.when(i == c1)
    def _chunk_fold():
        # the chunk attends itself causally, straight from VMEM — its K/V
        # never round-trips through HBM before being scored
        kc = kc_ref[0, 0].astype(jnp.float32)  # [t, Dh]
        vc = vc_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (t * g, t), 0) // g
        col = jax.lax.broadcasted_iota(jnp.int32, (t * g, t), 1)
        valid = (col <= qpos) & (col < ln)     # causal + pad rows masked
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        c = jnp.exp(m_ref[...] - m_new)
        p = jnp.exp(s - m_new) * valid
        z2 = z_ref[...] * c + jnp.sum(p, axis=-1, keepdims=True)
        acc2 = acc_ref[...] * c + jax.lax.dot(
            p, vc, preferred_element_type=jnp.float32)
        o_ref[0, 0] = (acc2 / jnp.maximum(z2, 1e-30)).astype(o_ref.dtype)

    @pl.when((i >= c0) & (i <= c1))
    def _splice():
        # fused scatter: rewrite this block with the chunk rows that land in
        # it spliced in (pool outputs alias the pool inputs; blocks outside
        # [c0, c1] are never written).  One-hot [bs, t] matmul instead of a
        # dynamic row gather.
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, t), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bs, t), 1)
        sel = (pos - start == col) & (col < ln)
        own = jnp.any(sel, axis=1, keepdims=True)          # [bs, 1]
        self_f = sel.astype(jnp.float32)
        kn = jax.lax.dot(self_f, kc_ref[0, 0].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        vn = jax.lax.dot(self_f, vc_ref[0, 0].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        ko_ref[0, 0] = jnp.where(own, kn.astype(ko_ref.dtype), kp_ref[0, 0])
        vo_ref[0, 0] = jnp.where(own, vn.astype(vo_ref.dtype), vp_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def paged_prefill_chunk_kernel(
        q: jax.Array, k_chunk: jax.Array, v_chunk: jax.Array,
        k_pool: jax.Array, v_pool: jax.Array,
        block_tables: jax.Array, start: jax.Array, lens: jax.Array,
        scale: float, softcap: float = 0.0, interpret: bool = False):
    """q [B, Hkv, T*g, Dh] (query row r = chunk position r//g);
    k_chunk/v_chunk [B, Hkv, T, Dh] (the chunk's projected KV); pools
    [N, Hkv, bs, Dh]; block_tables int32 [B, L]; start/lens int32 [B]
    (first write position / valid chunk length, lens >= 1).

    Returns (out [B, Hkv, T*g, Dh] in pool dtype, k_pool', v_pool') with the
    chunk's KV scattered into each row's blocks in place."""
    bq, hkv, tg, dh = q.shape
    t = k_chunk.shape[2]
    bs = k_pool.shape[2]
    n_log = block_tables.shape[1]
    g = tg // t

    def kv_map(b, h, i, start_ref, lens_ref, bt_ref):
        c1 = jnp.minimum((start_ref[b] + lens_ref[b] - 1) // bs, n_log - 1)
        return (bt_ref[b, jnp.minimum(i, c1)], h, 0, 0)

    def kv_out_map(b, h, i, start_ref, lens_ref, bt_ref):
        c0 = jnp.minimum(start_ref[b] // bs, n_log - 1)
        c1 = jnp.minimum((start_ref[b] + lens_ref[b] - 1) // bs, n_log - 1)
        return (bt_ref[b, jnp.clip(i, c0, c1)], h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bq, hkv, n_log),
        in_specs=[
            pl.BlockSpec((1, 1, tg, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), kv_map),
            pl.BlockSpec((1, 1, bs, dh), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tg, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), kv_out_map),
            pl.BlockSpec((1, 1, bs, dh), kv_out_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((tg, 1), jnp.float32),          # m
            pltpu.VMEM((tg, 1), jnp.float32),          # z
            pltpu.VMEM((tg, dh), jnp.float32),         # acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_log=n_log, t=t, g=g, scale=scale,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bq, hkv, tg, dh), k_pool.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # pool operands (positions 6/7 incl. the three scalar-prefetch args)
        # alias the pool outputs: the chunk scatter is in place, untouched
        # blocks keep their contents
        input_output_aliases={6: 1, 7: 2},
        interpret=interpret,
    )(start, lens, block_tables, q, k_chunk, v_chunk, k_pool, v_pool)
