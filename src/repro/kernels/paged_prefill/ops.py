"""Public wrapper: fused paged-prefill chunk attention over the block pools.

``paged_prefill_chunk`` is the serving entry point
(nn/attention.py:Attention.decode_chunk with ``attn_impl="fused"``):
model-layout q/k_chunk/v_chunk in, per-chunk-token attention context plus
in-place-updated pools out.  On CPU the kernel runs in interpret mode
(correctness path; the chunk-gather fallback is what "auto" serving selects
there).  Inference only — no VJP.

``prefill_kv_bytes`` is the per-chunk-step KV-traffic model shared by
benchmarks/speed_memory.py and launch/roofline.py: the fused kernel reads
``O(tokens resident)`` (one pass over each chunked row's resident + touched
blocks; the chunk's own KV is scored from VMEM), the gather fallback reads
the dense ``B * table_width * block_size`` window.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from repro.kernels.paged_prefill.kernel import paged_prefill_chunk_kernel


def _interpret_default() -> bool:
    # the kernel uses pltpu-only machinery (PrefetchScalarGridSpec, VMEM
    # scratch): any non-TPU backend must take the interpreter, not a
    # doomed native lowering
    return jax.default_backend() != "tpu"


def paged_prefill_chunk(q: jax.Array, k_chunk: jax.Array, v_chunk: jax.Array,
                        k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, start: jax.Array,
                        lens: jax.Array, softcap: float = 0.0,
                        interpret: Optional[bool] = None,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q [B, T, Hq, Dh] (RoPE'd); k_chunk/v_chunk [B, T, Hkv, Dh] (the
    chunk's projected KV); pools [N, Hkv, bs, Dh]; block_tables int32 [B, L];
    start/lens int32 [B].

    Chunk token ``j`` of row ``b`` is written at position ``start[b] + j``
    (valid iff ``j < lens[b]``) and attends stored positions
    ``<= start[b] + j``.  Returns (ctx [B, T, Hq, Dh] in pool dtype,
    k_pool', v_pool'); the chunk KV is scattered into each row's blocks in
    place (pass donated pools)."""
    itp = _interpret_default() if interpret is None else interpret
    b, t, hq, dh = q.shape
    hkv = k_pool.shape[1]
    g = hq // hkv
    # query row r = j*g + gi for chunk position j, grouped head gi
    qg = q.reshape(b, t, hkv, g, dh).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b, hkv, t * g, dh)
    kc = k_chunk.transpose(0, 2, 1, 3)              # [B, Hkv, T, Dh]
    vc = v_chunk.transpose(0, 2, 1, 3)
    scale = float(1.0 / (dh ** 0.5))
    out, k_pool, v_pool = paged_prefill_chunk_kernel(
        qg, kc, vc, k_pool, v_pool, block_tables, start, lens,
        scale=scale, softcap=float(softcap), interpret=itp)
    out = out.reshape(b, hkv, t, g, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, hq, dh), k_pool, v_pool


def prefill_kv_bytes(starts: Sequence[int], lens: Sequence[int],
                     chunked: Sequence[int], table_width: int,
                     block_size: int, n_kv_heads: int, head_dim: int,
                     n_layers: int, itemsize: int, fused: bool) -> int:
    """KV bytes read by one chunked-prefill step over the slot batch.

    ``starts``/``lens`` are the per-slot chunk start positions and valid
    lengths, ``chunked`` the slot indices that ran a chunk (prefilling or
    decoding — both attend), ``table_width`` the bucketed block-table width
    the engine passed down.  Gather: every slot pays the dense window.
    Fused: each chunked row streams its resident blocks (plus the partially
    written blocks the chunk splices) once; idle rows re-read a single trash
    block; the chunk's own KV is scored from VMEM and never re-read."""
    per_token = 2 * n_kv_heads * head_dim * itemsize * n_layers   # K and V
    n_slots = len(starts)
    if not fused:
        return n_slots * table_width * block_size * per_token
    blocks = 0
    chunked = set(chunked)
    for s in range(n_slots):
        if s in chunked:
            last = int(starts[s]) + max(int(lens[s]), 1) - 1
            blocks += min(last // block_size, table_width - 1) + 1
        else:
            blocks += 1                       # trash block, fetched once
    return blocks * block_size * per_token
