"""Pure-jnp oracle for the fused paged-prefill chunk kernel.

Deliberately the *materializing* formulation the kernel replaces: scatter the
chunk's K/V into the rows' pool blocks (pad rows to the trash block), gather
the whole block table into a dense ``[B, Hkv, L*bs, Dh]`` window, and run
masked dense softmax attention where chunk token ``j`` attends stored
positions ``<= start + j`` (resident prefix + causal within the chunk).
Matches nn/attention.py's chunk-gather fallback semantics; tests sweep shapes
and assert the kernel agrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38
TRASH_BLOCK = 0           # serving/paged.py convention: block 0 is reserved


def paged_prefill_chunk_ref(q: jax.Array, k_chunk: jax.Array,
                            v_chunk: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            start: jax.Array, lens: jax.Array, scale: float,
                            softcap: float = 0.0):
    """Same contract as kernel.paged_prefill_chunk_kernel:
    q [B, Hkv, T*g, Dh]; k_chunk/v_chunk [B, Hkv, T, Dh]; pools
    [N, Hkv, bs, Dh]; block_tables [B, L]; start/lens [B]
    -> (out [B, Hkv, T*g, Dh], k_pool', v_pool')."""
    b, hkv, tg, dh = q.shape
    t = k_chunk.shape[2]
    bs = k_pool.shape[2]
    nlog = block_tables.shape[1]
    g = tg // t

    pos = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None]     # [B, T]
    valid = jnp.arange(t, dtype=jnp.int32)[None] < lens[:, None]
    blk = jnp.minimum(pos // bs, nlog - 1)
    bid = jnp.take_along_axis(block_tables, blk, axis=1)            # [B, T]
    bid = jnp.where(valid, bid, TRASH_BLOCK)    # pad rows never land anywhere
    off = pos % bs
    kf = k_chunk.transpose(0, 2, 1, 3).reshape(b * t, hkv, dh)
    vf = v_chunk.transpose(0, 2, 1, 3).reshape(b * t, hkv, dh)
    k_pool = k_pool.at[bid.reshape(-1), :, off.reshape(-1)].set(
        kf.astype(k_pool.dtype))
    v_pool = v_pool.at[bid.reshape(-1), :, off.reshape(-1)].set(
        vf.astype(v_pool.dtype))

    k = k_pool[block_tables]                    # [B, L, Hkv, bs, Dh]
    v = v_pool[block_tables]
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nlog * bs, dh)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nlog * bs, dh)
    qg = q.reshape(b, hkv, t, g, dh)
    s = jnp.einsum("bktgd,bkpd->bktgp", qg.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    kvp = jnp.arange(nlog * bs, dtype=jnp.int32)
    mask = (kvp[None, None] <= pos[:, :, None])[:, None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bktgp,bkpd->bktgd", w, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hkv, tg, dh).astype(k_pool.dtype), k_pool, v_pool
