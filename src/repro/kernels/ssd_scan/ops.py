"""Public wrapper: model-layout SSD with the Pallas chunked kernel.

Forward runs the kernel; backward recomputes with the jnp chunked SSD
(repro.nn.ssm.ssd_chunked) under jax.checkpoint semantics — the chunked form
is linear in S, so the recompute costs one extra forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.nn.ssm import ssd_chunked


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_scan(x, a, dt, B, C, chunk: int = 256, interpret: bool | None = None):
    """Model layout: x [b,s,h,p], a/dt [b,s,h], B/C [b,s,n] -> y [b,s,h,p]."""
    itp = _interpret_default() if interpret is None else interpret
    b, s, h, p = x.shape
    n = B.shape[-1]
    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    ak = a.transpose(0, 2, 1).reshape(b * h, s)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s)
    Bk = jnp.repeat(B[:, None], h, axis=1).reshape(b * h, s, n)
    Ck = jnp.repeat(C[:, None], h, axis=1).reshape(b * h, s, n)
    y, _ = ssd_scan_kernel(xk, ak, dtk, Bk, Ck, chunk=chunk, interpret=itp)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


def _fwd(x, a, dt, B, C, chunk, interpret):
    return ssd_scan(x, a, dt, B, C, chunk, interpret), (x, a, dt, B, C)


def _bwd(chunk, interpret, res, g):
    x, a, dt, B, C = res

    def f(x_, a_, dt_, B_, C_):
        y, _ = ssd_chunked(x_.astype(jnp.float32), a_.astype(jnp.float32),
                           dt_.astype(jnp.float32), B_.astype(jnp.float32),
                           C_.astype(jnp.float32), chunk=chunk)
        return y

    _, vjp = jax.vjp(f, x, a, dt, B, C)
    dx, da, ddt, dB, dC = vjp(g.astype(jnp.float32))
    return (dx.astype(x.dtype), da.astype(a.dtype), ddt.astype(dt.dtype),
            dB.astype(B.dtype), dC.astype(C.dtype))


ssd_scan.defvjp(_fwd, _bwd)
