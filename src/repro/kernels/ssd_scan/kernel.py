"""Mamba2 SSD chunked-scan kernel (used by mamba2 / jamba archs).

One (batch·head, chunk) grid cell computes a full SSD chunk:

  la        = cumsum(log a)                       (VPU, fp32)
  y_intra   = ((C Bᵀ) ⊙ decay ⊙ dt) x            (two MXU matmuls)
  y_off     = (C h_prev) ⊙ exp(la)               (MXU)
  h_next    = h_prev·exp(la_last) + Bᵀ(dt·exp(la_last-la) ⊙ x)

The recurrent state h [P, N] lives in a VMEM scratch that persists across the
sequential chunk dimension of the grid (TPU grids iterate in order), so the
inter-chunk recurrence costs no HBM round-trips.  B/C are pre-broadcast per
head by ops.py (n_groups=1 in all our configs; the N=128 copies are small
next to x).

Grid (BH, S/Q); Q = chunk length (128/256 keeps every matmul MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256


def _kernel(x_ref, a_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
            *, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # [Q, P]
    a = a_ref[0].astype(jnp.float32)        # [Q]
    dt = dt_ref[0].astype(jnp.float32)      # [Q]
    B = b_ref[0].astype(jnp.float32)        # [Q, N]
    C = c_ref[0].astype(jnp.float32)        # [Q, N]
    q = x.shape[0]

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-20)))            # [Q]
    seg = la[:, None] - la[None, :]                            # [Q, Q]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    decay = jnp.where(causal, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    m = cb * decay * dt[None, :]
    y = jax.lax.dot(m, x, preferred_element_type=jnp.float32)     # intra

    h = h_ref[...]                                                # [N, P]
    y = y + jnp.exp(la)[:, None] * jax.lax.dot(
        C, h, preferred_element_type=jnp.float32)                 # off-diag

    la_last = la[q - 1]
    wk = dt * jnp.exp(la_last - la)                               # [Q]
    h_ref[...] = h * jnp.exp(la_last) + jax.lax.dot_general(
        B, wk[:, None] * x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # [N, P]

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _finish():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x: jax.Array, a: jax.Array, dt: jax.Array, B: jax.Array,
                    C: jax.Array, chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False):
    """x [BH, S, P]; a/dt [BH, S]; B/C [BH, S, N] -> (y [BH, S, P], h [BH, N, P])."""
    bh, s, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    while s % q:
        q -= 1
    # the loop above shrank q until it divides s exactly, so // drops nothing
    grid = (bh, s // q)  # lint: allow(pallas-grid-div)
    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q), lambda b, c: (b, c)),
            pl.BlockSpec((1, q), lambda b, c: (b, c)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, p), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, a, dt, B, C)
