"""Oracle: sequential scan over time (repro.nn.ssm.ssd_sequential reshaped)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.ssm import ssd_sequential


def ssd_scan_ref(x: jax.Array, a: jax.Array, dt: jax.Array, B: jax.Array,
                 C: jax.Array):
    """Same [BH, ...] layout as the kernel; returns (y, final_state [BH,N,P])."""
    bh, s, p = x.shape
    y, h = ssd_sequential(
        x.reshape(bh, s, 1, p).transpose(0, 2, 1, 3).transpose(0, 2, 1, 3),
        a[:, :, None], dt[:, :, None], B, C)
    # ssd_sequential wants [b, s, h, p]; we mapped bh->b with h=1
    return y[:, :, 0, :], jnp.moveaxis(h[:, 0], -1, -2)  # [bh, n, p]
