"""Flash-style MiniLM relation-KL kernel (Algorithm 1, Eq. 11-12).

Computes, per relation row i:
    KL_i = sum_j P_t(i,j) * (log P_t(i,j) - log P_s(i,j))
where P_t = softmax_j(t_i·t_j / temp) and P_s = softmax_j(s_i·s_j / temp),
WITHOUT materializing the L×L relation matrices.  Streaming over j-blocks
with online (rescaled) accumulators:

    m_t, z_t   — running max / sum of exp for the teacher row
    m_s, z_s   — same for the student row
    u          — running sum of exp(t_rel - m_t) * (t_rel - s_rel)

then  KL_i = u/z_t - (m_t + log z_t) + (m_s + log z_s).

(The identity: sum_j p_j (t_j - s_j) - logZt + logZs with p the teacher
softmax; u accumulates the unnormalized first term.)

Inputs are the already L2-normalized, head-resplit states [BH, L, D]
(ops.py does that cheap prep).  HBM traffic: O(BH·L·D) instead of
O(BH·L²) — at L = 4096, split_heads·B = 32, that is ~0.5 GB of relation
matrices per relation per model that never exist.

Grid (BH, L/bl, L/bj); j innermost; accumulators live in VMEM scratch and
the per-row KL is written on the last j step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BL = 256
DEFAULT_BJ = 256
NEG = -1e30


def _kernel(s_i_ref, t_i_ref, s_j_ref, t_j_ref, o_ref,
            mt_ref, zt_ref, ms_ref, zs_ref, u_ref,
            *, n_j: int, temp: float, l: int):
    j_idx = pl.program_id(2)

    @pl.when(j_idx == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG)
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        zt_ref[...] = jnp.zeros_like(zt_ref)
        zs_ref[...] = jnp.zeros_like(zs_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    s_i = s_i_ref[0].astype(jnp.float32)          # [bl, D]
    t_i = t_i_ref[0].astype(jnp.float32)
    s_j = s_j_ref[0].astype(jnp.float32)          # [bj, D]
    t_j = t_j_ref[0].astype(jnp.float32)
    bj = s_j.shape[0]

    t_rel = jax.lax.dot_general(t_i, t_j, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) / temp
    s_rel = jax.lax.dot_general(s_i, s_j, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) / temp

    # mask padded j columns (L not divisible by bj): exp(NEG - m) == 0
    col_ok = (j_idx * bj + jax.lax.broadcasted_iota(jnp.int32, (1, bj), 1)) < l
    t_rel = jnp.where(col_ok, t_rel, NEG)
    s_rel = jnp.where(col_ok, s_rel, NEG)

    # online rescale of the three accumulators
    mt_old, ms_old = mt_ref[...], ms_ref[...]              # [bl, 1]
    mt_new = jnp.maximum(mt_old, jnp.max(t_rel, axis=-1, keepdims=True))
    ms_new = jnp.maximum(ms_old, jnp.max(s_rel, axis=-1, keepdims=True))
    ct = jnp.exp(mt_old - mt_new)
    cs = jnp.exp(ms_old - ms_new)

    pt = jnp.exp(t_rel - mt_new)                           # [bl, bj]
    zt_ref[...] = zt_ref[...] * ct + jnp.sum(pt, axis=-1, keepdims=True)
    zs_ref[...] = zs_ref[...] * cs + jnp.sum(jnp.exp(s_rel - ms_new),
                                             axis=-1, keepdims=True)
    u_ref[...] = u_ref[...] * ct + jnp.sum(pt * (t_rel - s_rel),
                                           axis=-1, keepdims=True)
    mt_ref[...] = mt_new
    ms_ref[...] = ms_new

    @pl.when(j_idx == n_j - 1)
    def _finish():
        zt = jnp.maximum(zt_ref[...], 1e-30)
        zs = jnp.maximum(zs_ref[...], 1e-30)
        kl = (u_ref[...] / zt
              - (mt_ref[...] + jnp.log(zt))
              + (ms_ref[...] + jnp.log(zs)))
        o_ref[0] = kl[:, 0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "bj", "temp", "interpret"))
def relation_kl_rows_kernel(s: jax.Array, t: jax.Array, temp: float = 1.0,
                            bl: int = DEFAULT_BL, bj: int = DEFAULT_BJ,
                            interpret: bool = False) -> jax.Array:
    """s, t: [BH, L, D] L2-normalized relation vectors -> KL rows [BH, L]."""
    bh, l, d = s.shape
    bl, bj = min(bl, l), min(bj, l)
    grid = (bh, pl.cdiv(l, bl), pl.cdiv(l, bj))
    return pl.pallas_call(
        functools.partial(_kernel, n_j=grid[2], temp=temp, l=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bl, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bj, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bj, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bl), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((bh, l), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bl, 1), jnp.float32) for _ in range(5)],
        interpret=interpret,
    )(s, t, s, t)
