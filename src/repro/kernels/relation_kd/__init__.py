from repro.kernels.relation_kd import kernel, ops, ref  # noqa: F401
