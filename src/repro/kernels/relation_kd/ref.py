"""Pure-jnp oracle: materializes the L×L relation matrices (core.distill)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distill import _l2_normalize, _resplit_heads


def relation_kl_rows_ref(s: jax.Array, t: jax.Array, temp: float = 1.0) -> jax.Array:
    """s, t: [BH, L, D] (already normalized) -> KL(t_row ‖ s_row) [BH, L]."""
    s_rel = jnp.einsum("bld,bmd->blm", s, s) / temp
    t_rel = jnp.einsum("bld,bmd->blm", t, t) / temp
    s_logp = jax.nn.log_softmax(s_rel, axis=-1)
    t_logp = jax.nn.log_softmax(t_rel, axis=-1)
    t_prob = jnp.exp(t_logp)
    return jnp.sum(t_prob * (t_logp - s_logp), axis=-1)


def prep_states(states: jax.Array, split_heads: int) -> jax.Array:
    """[B, H, L, Dh] -> normalized resplit [B*split, L, D] (ops.py prep)."""
    x = _l2_normalize(_resplit_heads(states.astype(jnp.float32), split_heads))
    b, h, l, d = x.shape
    return x.reshape(b * h, l, d)


def relation_kd_loss_ref(student_states: jax.Array, teacher_states: jax.Array,
                         split_heads: int, temperature: float = 1.0,
                         alphas=(1.0, 1.0, 1.0)) -> jax.Array:
    """[3, B, H, L, Dh] x2 -> scalar; must equal core.distill.attention_relation_loss."""
    total = jnp.zeros((), jnp.float32)
    for i in range(3):
        s = prep_states(student_states[i], split_heads)
        t = prep_states(teacher_states[i], split_heads)
        total = total + alphas[i] * jnp.mean(relation_kl_rows_ref(s, t, temperature))
    return total
