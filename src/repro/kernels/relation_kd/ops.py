"""Public wrapper: relation-KD loss with flash forward + blocked custom VJP.

Backward derivation (needed because the kernel is forward-only):
  KL_i = Σ_j P_t(i,j)(log P_t - log P_s)   with  s_rel = n_s n_sᵀ / temp.
  ∂KL_i/∂s_rel(i,k) = (P_s(i,k) - P_t(i,k))        (teacher is stop-grad)
  ⇒ with W = diag(row_weights)·(P_s - P_t)/temp:
     g_{n_s} = W n_s + Wᵀ n_s.
The backward recomputes P_s/P_t in row blocks (never the full L×L at once)
via a lax.scan that carries the [L, D] gradient accumulator, then chains
through the L2-normalize + head-resplit with standard jnp autodiff.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.distill import _l2_normalize, _resplit_heads
from repro.kernels.relation_kd.kernel import relation_kl_rows_kernel

BWD_BLOCK = 512


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _prep(states: jax.Array, split_heads: int) -> jax.Array:
    x = _l2_normalize(_resplit_heads(states.astype(jnp.float32), split_heads))
    b, h, l, d = x.shape
    return x.reshape(b * h, l, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _relation_mean_kl(s_norm: jax.Array, t_norm: jax.Array, temp: float,
                      block: int, interpret: bool) -> jax.Array:
    """mean over (BH, L) rows of KL; s_norm/t_norm [BH, L, D] normalized."""
    rows = relation_kl_rows_kernel(s_norm, t_norm, temp=temp,
                                   interpret=interpret)
    return jnp.mean(rows)


def _fwd(s_norm, t_norm, temp, block, interpret):
    return _relation_mean_kl(s_norm, t_norm, temp, block, interpret), (s_norm, t_norm)


def _bwd(temp, block, interpret, res, g):
    s, t = res
    bh, l, d = s.shape
    scale = g / (bh * l)                 # d(mean)/d(row KL)
    block = min(block, l)
    nb = -(-l // block)
    pad = nb * block - l

    sp = jnp.pad(s, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
    valid = (jnp.arange(nb * block) < l)

    def body(acc_c, i):
        sl = jax.lax.dynamic_slice_in_dim(sp, i * block, block, axis=1)
        tl = jax.lax.dynamic_slice_in_dim(tp, i * block, block, axis=1)
        rowv = jax.lax.dynamic_slice_in_dim(valid, i * block, block)
        s_rel = jnp.einsum("bld,bmd->blm", sl, s) / temp      # [bh, block, L]
        t_rel = jnp.einsum("bld,bmd->blm", tl, t) / temp
        w = (jax.nn.softmax(s_rel, axis=-1)
             - jax.nn.softmax(t_rel, axis=-1)) / temp
        w = w * rowv[None, :, None].astype(jnp.float32) * scale
        # row term: g[rows of this block] = W @ n ; col term: g[all] += Wᵀ @ n_rows
        g_rows = jnp.einsum("blm,bmd->bld", w, s)             # [bh, block, d]
        acc_c = acc_c + jnp.einsum("blm,bld->bmd", w, sl)     # [bh, l, d]
        return acc_c, g_rows

    acc_c, rows = jax.lax.scan(body, jnp.zeros_like(s), jnp.arange(nb))
    g_rows_full = jnp.moveaxis(rows, 0, 1).reshape(bh, nb * block, d)[:, :l]
    return acc_c + g_rows_full, None


_relation_mean_kl.defvjp(_fwd, _bwd)


def relation_kd_loss(student_states: jax.Array, teacher_states: jax.Array,
                     split_heads: int = 4, temperature: float = 1.0,
                     alphas: Tuple[float, float, float] = (1.0, 1.0, 1.0),
                     interpret: bool | None = None) -> jax.Array:
    """[3, B, H, L, Dh] x2 -> scalar Eq. 11 loss (flash path)."""
    itp = _interpret_default() if interpret is None else interpret
    total = jnp.zeros((), jnp.float32)
    for i in range(3):
        s = _prep(student_states[i], split_heads)
        t = jax.lax.stop_gradient(_prep(teacher_states[i], split_heads))
        total = total + alphas[i] * _relation_mean_kl(
            s, t, float(temperature), BWD_BLOCK, itp)
    return total
