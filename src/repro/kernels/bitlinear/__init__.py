from repro.kernels.bitlinear import kernel, ops, ref  # noqa: F401
