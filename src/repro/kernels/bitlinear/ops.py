"""Public wrapper: QAT-compatible fused bitlinear matmul with STE backward.

Forward runs the Pallas kernel (int8 MXU path); backward applies the STE:
  dx = g @ (Δ·wq)ᵀ ,  dw = x_dequantᵀ @ g
which is exactly the gradient of the fake-quant reference under
straight-through estimation of both quantizers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.kernels.bitlinear.kernel import bitlinear_kernel


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _fwd_2d(x2d: jax.Array, w: jax.Array, scheme: str, interpret: bool):
    gamma = jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=-1, keepdims=True)
    if scheme == "absmean":
        qw, delta = Q.weight_quant_absmean(w)
    else:  # kernel path supports per-tensor scales; other schemes fall back
        qw, delta = Q.weight_quant_absmean(w)
    y = bitlinear_kernel(x2d, qw.astype(jnp.int8), gamma,
                         delta.astype(jnp.float32), interpret=interpret)
    return y, (gamma, qw, delta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def bitlinear_matmul(x: jax.Array, w: jax.Array, scheme: str = "absmean",
                     interpret: bool | None = None) -> jax.Array:
    """x [..., K] float; w [K, N] float (unquantized master weight)."""
    itp = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    y, _ = _fwd_2d(x2d, w, scheme, itp)
    return y.reshape(*lead, w.shape[-1])


def _vjp_fwd(x, w, scheme, interpret):
    itp = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    y, (gamma, qw, delta) = _fwd_2d(x2d, w, scheme, itp)
    return y.reshape(*lead, w.shape[-1]), (x2d, gamma, qw, delta, lead)


def _vjp_bwd(scheme, interpret, res, g):
    x2d, gamma, qw, delta, lead = res
    g2d = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    w_deq = qw.astype(jnp.float32) * delta
    # STE through activation quant: dequantized activations for dw
    xq = jnp.clip(jnp.round(x2d.astype(jnp.float32) * (127.0 / (gamma + 1e-5))),
                  -128, 127)
    x_deq = xq * (gamma / 127.0)
    dx = jnp.matmul(g2d, w_deq.T).reshape(*lead, x2d.shape[-1]).astype(jnp.float32)
    dw = jnp.matmul(x_deq.T, g2d)
    return dx, dw


bitlinear_matmul.defvjp(_vjp_fwd, _vjp_bwd)
