"""Fused W1.58·A8 matmul kernel.

y[M, N] = ( round_clip(127·x/γ) @ wq ) · (γ·Δ/127)

with wq ∈ {-1,0,1} int8 (pre-ternarized, per-tensor scale Δ) and γ the
per-token absmax (computed by ops.py in one cheap fused reduce — per-token
scales need the full K row, so they cannot live inside a K-blocked kernel).

TPU mapping: the MXU multiplies int8×int8→int32 at 2× bf16 throughput; the
kernel quantizes the activation tile in VMEM (VPU), issues the int8 dot, and
rescales the fp32 accumulator on the final K step — the TPU-native analogue
of bitnet.cpp's CPU LUT kernels (DESIGN.md §3).

Grid (M/bm, N/bn, K/bk); K is innermost so the fp32 accumulator tile lives in
a VMEM scratch across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, gamma_ref, delta_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-token int8 quantization of the activation tile (γ is full-row absmax)
    x = x_ref[...].astype(jnp.float32)
    gamma = gamma_ref[...].astype(jnp.float32)            # [bm, 1]
    xq = jnp.clip(jnp.round(x * (127.0 / (gamma + 1e-5))), -128, 127)
    xq = xq.astype(jnp.int8)

    w = w_ref[...]                                         # int8 ternary [bk, bn]
    acc_ref[...] += jax.lax.dot(
        xq, w, preferred_element_type=jnp.int32,
        precision=jax.lax.Precision.DEFAULT).astype(jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        scale = (gamma / 127.0) * delta_ref[0]             # [bm, 1]
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def bitlinear_kernel(x: jax.Array, wq: jax.Array, gamma: jax.Array,
                     delta: jax.Array, bm: int = DEFAULT_BM,
                     bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """x [M, K] float; wq [K, N] int8; gamma [M, 1] f32; delta scalar f32."""
    m, k = x.shape
    _, n = wq.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # scalar delta broadcast
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, wq, gamma, delta.reshape(1))
