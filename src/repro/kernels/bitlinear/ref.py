"""Pure-jnp oracle for the fused bitlinear matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q


def bitlinear_ref(x: jax.Array, wq: jax.Array, gamma: jax.Array,
                  delta: jax.Array) -> jax.Array:
    """Same math as the kernel, materialized: int8 activations, ternary w."""
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * (127.0 / (gamma + 1e-5))),
                  -128, 127)
    acc = jnp.matmul(xq, wq.astype(jnp.float32))
    return (acc * (gamma / 127.0) * delta).astype(x.dtype)


def bitlinear_full_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """End-to-end oracle from the *unquantized* weight (matches BitLinear qat
    forward): fake-quant activations and weights, then matmul."""
    xq, gamma = Q.act_quant_absmax_int8(x)
    deq_x = xq.astype(jnp.float32) * (gamma / 127.0)
    qw, delta = Q.weight_quant_absmean(w)
    return jnp.matmul(deq_x, qw.astype(jnp.float32) * delta).astype(x.dtype)
