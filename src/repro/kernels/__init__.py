"""Pallas TPU kernels for BitDistill's compute hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (+ custom_vjp where used in training)
  ref.py    — pure-jnp oracle; tests sweep shapes/dtypes and assert_allclose

Kernels target TPU v5e (MXU 128x128 int8/bf16, ~16 MB VMEM); on this CPU
container they are validated with ``interpret=True``.
"""
