"""Fused paged-attention decode kernel (serving hot path).

One decode step of paged attention without the dense block-table gather:
instead of materializing ``pool[block_tables]`` as a ``[B, L, Hkv, bs, Dh]``
buffer (worst-case bandwidth, exactly what the paged layout was meant to
kill), each (row, kv-head) grid cell streams the row's KV blocks straight out
of the shared pools and folds them into a flash-style online-softmax carry.

Grid / blocking scheme
----------------------
Grid ``(B, Hkv, L)`` with the logical-block dimension innermost; TPU grids
iterate in order, so the fp32 (m, z, acc) carry lives in VMEM scratch that
persists across a row's blocks (same trick as ssd_scan's recurrent state).
``block_tables`` and the per-row write positions ``idx`` ride in as
scalar-prefetch operands: the K/V pool BlockSpec index maps read
``bt[b, min(i, idx[b] // bs)]`` to pick which physical pool block the
pipeline fetches next.  Because consecutive grid steps that map to the same
block skip the re-fetch, rows shallower than the table width cost no extra
HBM traffic past their last resident block — KV bytes read per step are
``O(tokens resident)``, not ``O(B * L * bs)``.

In-kernel semantics (mirrors nn/attention.py's gather fallback):

  * stored positions ``p < idx[b]`` attend; garbage beyond the row's write
    position — trash-block contents, stale partial-last-block slots — is
    masked by zeroing its softmax weight (mask multiplies the exp term, so a
    fully-masked block contributes exactly nothing to the carry);
  * the step's new K/V (position ``idx[b]``) never round-trips through HBM:
    its score folds into the carry at the row's last block, and the
    scatter-write into the row's current pool block is fused — the kernel
    rewrites that one block with the new row spliced in, via pool outputs
    aliased onto the pool inputs (every other block is untouched);
  * idle rows (block table all trash, parked write position) stream the
    trash block and produce finite garbage the caller discards — no
    occupancy branch, same contract as the gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(idx_ref, bt_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
            o_ref, ko_ref, vo_ref, m_ref, z_ref, acc_ref,
            *, bs: int, n_log: int, scale: float, softcap: float):
    b, i = pl.program_id(0), pl.program_id(2)
    idx = idx_ref[b]
    lim = jnp.minimum(idx // bs, n_log - 1)    # row's last resident block

    @pl.when(i <= lim)
    def _process():
        @pl.when(i == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            z_ref[...] = jnp.zeros_like(z_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0].astype(jnp.float32)            # [g, Dh]
        kb = kp_ref[0, 0].astype(jnp.float32)          # [bs, Dh]
        vb = vp_ref[0, 0].astype(jnp.float32)
        g = q.shape[0]

        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        valid = pos < idx                              # stored tokens only
        # mask by zeroing the exp term (not by NEG_INF scores): a block with
        # no stored tokens must contribute exactly nothing to the carry even
        # while m is still at its NEG_INF init (exp(NEG-NEG)=1 would leak)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        c = jnp.exp(m_ref[...] - m_new)
        p = jnp.exp(s - m_new) * valid
        m_ref[...] = m_new
        z_ref[...] = z_ref[...] * c + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * c + jax.lax.dot(
            p, vb, preferred_element_type=jnp.float32)

        @pl.when(i == lim)
        def _finish():
            # fused scatter: splice the new K/V row into the current block
            # and write that one block back (pool outputs alias the inputs)
            kn = kn_ref[0, 0]                          # [Dh], model dtype
            vn = vn_ref[0, 0]
            off = idx % bs
            row = jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0) == off
            ko_ref[0, 0] = jnp.where(row, kn[None].astype(ko_ref.dtype),
                                     kp_ref[0, 0])
            vo_ref[0, 0] = jnp.where(row, vn[None].astype(vo_ref.dtype),
                                     vp_ref[0, 0])
            # fold the new token (position idx, always attended) into the
            # carry without an HBM round-trip, then normalize
            sn = jnp.sum(q * kn.astype(jnp.float32)[None], axis=-1,
                         keepdims=True) * scale        # [g, 1]
            if softcap > 0.0:
                sn = softcap * jnp.tanh(sn / softcap)
            m2 = jnp.maximum(m_ref[...], sn)
            c2 = jnp.exp(m_ref[...] - m2)
            pn = jnp.exp(sn - m2)
            z2 = z_ref[...] * c2 + pn
            acc2 = acc_ref[...] * c2 + pn * vn.astype(jnp.float32)[None]
            o_ref[0, 0] = (acc2 / jnp.maximum(z2, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def paged_attention_decode_kernel(
        q: jax.Array, k_new: jax.Array, v_new: jax.Array,
        k_pool: jax.Array, v_pool: jax.Array,
        block_tables: jax.Array, idx: jax.Array,
        scale: float, softcap: float = 0.0, interpret: bool = False):
    """q [B, Hkv, g, Dh]; k_new/v_new [B, Hkv, Dh]; pools [N, Hkv, bs, Dh];
    block_tables int32 [B, L]; idx int32 [B] (per-row write position).

    Returns (out [B, Hkv, g, Dh] in pool dtype, k_pool', v_pool') with the
    new K/V scattered into each row's current block in place."""
    bq, hkv, g, dh = q.shape
    n, _, bs, _ = k_pool.shape
    n_log = block_tables.shape[1]

    def kv_map(b, h, i, idx_ref, bt_ref):
        j = jnp.minimum(i, jnp.minimum(idx_ref[b] // bs, n_log - 1))
        return (bt_ref[b, j], h, 0, 0)

    def kv_out_map(b, h, i, idx_ref, bt_ref):
        cur = jnp.minimum(idx_ref[b] // bs, n_log - 1)
        return (bt_ref[b, cur], h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bq, hkv, n_log),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h, i, *_: (b, h, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h, i, *_: (b, h, 0)),
            pl.BlockSpec((1, 1, bs, dh), kv_map),
            pl.BlockSpec((1, 1, bs, dh), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), kv_out_map),
            pl.BlockSpec((1, 1, bs, dh), kv_out_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),           # m
            pltpu.VMEM((g, 1), jnp.float32),           # z
            pltpu.VMEM((g, dh), jnp.float32),          # acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_log=n_log, scale=scale,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bq, hkv, g, dh), k_pool.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # pool operands (positions 5/6 incl. the two scalar-prefetch args)
        # alias the pool outputs: the scatter is in place, untouched blocks
        # keep their contents
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(idx, block_tables, q, k_new, v_new, k_pool, v_pool)
