"""Fused paged-attention decode: stream KV blocks via the block table.

Grid (B, Hkv, L) with the logical-block dim innermost; an online-softmax
(m, z, acc) carry lives in VMEM scratch across a row's blocks, the block
table and per-row write positions arrive as scalar-prefetch operands that
drive the pool BlockSpec index maps, and the step's new K/V is both folded
into the carry and scatter-written into the row's current pool block through
aliased pool outputs.  KV bytes read per decode step are O(tokens resident)
instead of the gather fallback's O(B * table_width * block_size).  See
kernel.py for the full blocking scheme.
"""
from repro.kernels.paged_attention import kernel, ops, ref  # noqa: F401
