"""Pure-jnp oracle for the fused paged-attention decode kernel.

Deliberately the *materializing* formulation the kernel replaces: scatter the
new K/V into the row's current pool block, gather the whole block table into
a dense ``[B, Hkv, L*bs, Dh]`` window, run masked dense softmax attention
(positions ``<= idx``).  Matches nn/attention.py's gather fallback
semantics; tests sweep shapes and assert the kernel agrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def paged_attention_decode_ref(q: jax.Array, k_new: jax.Array,
                               v_new: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               idx: jax.Array, scale: float,
                               softcap: float = 0.0):
    """Same contract as kernel.paged_attention_decode_kernel:
    q [B, Hkv, g, Dh]; k_new/v_new [B, Hkv, Dh]; pools [N, Hkv, bs, Dh];
    block_tables [B, L]; idx [B] -> (out [B, Hkv, g, Dh], k_pool', v_pool')."""
    b, hkv, g, dh = q.shape
    bs = k_pool.shape[2]
    nlog = block_tables.shape[1]
    blk = jnp.minimum(idx // bs, nlog - 1)
    bid = jnp.take_along_axis(block_tables, blk[:, None], 1)[:, 0]
    off = idx % bs
    k_pool = k_pool.at[bid, :, off].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[bid, :, off].set(v_new.astype(v_pool.dtype))
    k = k_pool[block_tables]                  # [B, L, Hkv, bs, Dh]
    v = v_pool[block_tables]
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nlog * bs, dh)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nlog * bs, dh)
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    t = nlog * bs
    mask = (jnp.arange(t)[None] <= idx[:, None])[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(k_pool.dtype), k_pool, v_pool
