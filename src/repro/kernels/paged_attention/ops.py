"""Public wrapper: fused paged-attention decode over the serving block pools.

``paged_attention_decode`` is the serving entry point
(nn/attention.py:Attention.decode with ``attn_impl="fused"``): model-layout
q/k_new/v_new in, attention context plus in-place-updated pools out.  On CPU
the kernel runs in interpret mode (correctness path; the gather fallback is
what "auto" serving selects there).  Inference only — no VJP.

``decode_kv_bytes`` is the shared per-step KV-traffic model used by
benchmarks/speed_memory.py and launch/roofline.py: the fused kernel reads
``O(tokens resident)`` (one pass over each active row's resident blocks,
plus one trash block per idle row), the gather fallback reads the dense
``B * table_width * block_size`` window.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_decode_kernel


def _interpret_default() -> bool:
    # the kernel uses pltpu-only machinery (PrefetchScalarGridSpec, VMEM
    # scratch): any non-TPU backend must take the interpreter, not a
    # doomed native lowering
    return jax.default_backend() != "tpu"


def paged_attention_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                           k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array, idx: jax.Array,
                           softcap: float = 0.0,
                           interpret: Optional[bool] = None,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q [B, Hq, Dh] (RoPE'd); k_new/v_new [B, Hkv, Dh] (the step's KV);
    pools [N, Hkv, bs, Dh]; block_tables int32 [B, L]; idx int32 [B].

    Returns (ctx [B, Hq, Dh] in pool dtype, k_pool', v_pool'); the new K/V
    is scattered into each row's current block in place (pass donated
    pools)."""
    itp = _interpret_default() if interpret is None else interpret
    b, hq, dh = q.shape
    hkv = k_pool.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh)
    scale = float(1.0 / (dh ** 0.5))
    out, k_pool, v_pool = paged_attention_decode_kernel(
        qg, k_new, v_new, k_pool, v_pool, block_tables, idx,
        scale=scale, softcap=float(softcap), interpret=itp)
    return out.reshape(b, hq, dh), k_pool, v_pool


def decode_kv_bytes(positions: Sequence[int], active: Sequence[int],
                    table_width: int, block_size: int, n_kv_heads: int,
                    head_dim: int, n_layers: int, itemsize: int,
                    fused: bool) -> int:
    """KV bytes read by one decode step over the slot batch.

    ``positions`` are the per-slot write positions, ``active`` the occupied
    slot indices, ``table_width`` the bucketed block-table width the engine
    passed down.  Gather: every row pays the dense window.  Fused: each
    active row streams its resident blocks once; idle rows re-read a single
    trash block (consecutive same-block fetches are skipped)."""
    per_token = 2 * n_kv_heads * head_dim * itemsize * n_layers   # K and V
    n_slots = len(positions)
    if not fused:
        return n_slots * table_width * block_size * per_token
    blocks = 0
    active = set(active)
    for s in range(n_slots):
        if s in active:
            blocks += min(int(positions[s]) // block_size,
                          table_width - 1) + 1
        else:
            blocks += 1                       # trash block, fetched once
    return blocks * block_size * per_token
