"""Memory-bound decode GEMM/GEMV with 2-bit packed ternary weights.

Decode is HBM-bandwidth bound and weight bytes dominate; storing ternary
weights 4-per-byte cuts the HBM→VMEM weight DMA 8× vs bf16 (4× vs int8).
The kernel unpacks *after* the DMA, in VMEM, so the bandwidth saving is real:

  y[M, N] = ( q8(x) @ unpack(wp) ) · (γ/127 · Δ)

wp is uint8 [K/4, N] packed little-endian along K (quant.pack_ternary).  The
unpack is 3 shift+mask VPU ops per 4 weights; at M (decode batch) ≤ ~64 the
MXU is idle anyway, so trading VPU cycles for 8× less DMA is the right TPU
adaptation of bitnet.cpp's TL LUT kernels (DESIGN.md §3).

Grid (M/bm, N/bn, K/bk), K innermost, fp32 accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 256
DEFAULT_BK = 512


def _unpack(wp: jax.Array, bk: int) -> jax.Array:
    """uint8 [bk/4, bn] -> int8 {-1,0,1} [bk, bn] (little-endian 2-bit)."""
    parts = [((wp >> (2 * i)) & 0x3).astype(jnp.int8) - 1 for i in range(4)]
    return jnp.stack(parts, axis=1).reshape(bk, wp.shape[1])


def _kernel(x_ref, wp_ref, gamma_ref, delta_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    gamma = gamma_ref[...].astype(jnp.float32)
    xq = jnp.clip(jnp.round(x * (127.0 / (gamma + 1e-5))), -128, 127).astype(jnp.int8)

    w = _unpack(wp_ref[...], x.shape[1])
    acc_ref[...] += jax.lax.dot(
        xq, w, preferred_element_type=jnp.int32).astype(jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        scale = (gamma / 127.0) * delta_ref[0]
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w2a8_kernel(x: jax.Array, wp: jax.Array, gamma: jax.Array,
                delta: jax.Array, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """x [M, K]; wp uint8 [K//4, N]; gamma [M,1]; delta scalar -> y [M, N]."""
    m, k = x.shape
    kp, n = wp.shape
    if kp * 4 != k:
        raise ValueError(f"wp has {kp} packed rows but x has k={k} columns; "
                         "pack_ternary packs 4 weights per byte, so wp must "
                         "have exactly k/4 rows")
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if bk % 4 != 0:
        raise ValueError(f"bk={bk} must be a multiple of 4 to unpack whole "
                         "bytes of 2-bit weights per K tile")
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, wp, gamma, delta.reshape(1))
