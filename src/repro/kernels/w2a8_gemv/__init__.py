from repro.kernels.w2a8_gemv import kernel, ops, ref  # noqa: F401
