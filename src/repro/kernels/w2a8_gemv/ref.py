"""Pure-jnp oracle for the packed ternary GEMV."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q


def w2a8_ref(x: jax.Array, wp: jax.Array, delta: jax.Array) -> jax.Array:
    k = x.shape[-1]
    wq = Q.unpack_ternary(wp, k)
    xq, gamma = Q.act_quant_absmax_int8(x)
    acc = jnp.matmul(xq.astype(jnp.float32), wq.astype(jnp.float32))
    return (acc * (gamma / 127.0) * delta).astype(x.dtype)
