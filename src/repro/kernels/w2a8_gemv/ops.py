"""Public wrapper for the packed ternary matmul (inference only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.w2a8_gemv.kernel import w2a8_kernel


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def w2a8_matmul(x: jax.Array, wp: jax.Array, delta: jax.Array,
                interpret: bool | None = None) -> jax.Array:
    """x [..., K] float; wp uint8 [K//4, N]; delta scalar -> [..., N]."""
    itp = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    gamma = jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=-1, keepdims=True)
    y = w2a8_kernel(x2d, wp, gamma, jnp.asarray(delta, jnp.float32),
                    interpret=itp)
    return y.reshape(*lead, wp.shape[-1])
