"""End-to-end driver: the paper's full three-stage BitDistill pipeline on a
~1M-param model, a few hundred steps — FP16-SFT teacher -> SubLN refinement
-> continual pre-training -> distillation fine-tuning -> eval, with the
BitNet-SFT baseline for comparison.

    PYTHONPATH=src python examples/bitdistill_pipeline.py [--steps 250]
"""
import argparse
import json

import jax

from repro.core.distill import DistillConfig
from repro.core.pipeline import BitDistillPipeline, PipelineConfig
from repro.models.base import ModelConfig

CFG = ModelConfig(name="example-100m-proxy", family="dense", vocab=288,
                  d_model=128, n_layers=3, n_heads=4, n_kv_heads=4,
                  head_dim=32, d_ff=256, qk_norm=True,
                  param_dtype="float32", compute_dtype="float32",
                  remat=False, max_seq=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--task", default="sst2-syn")
    args = ap.parse_args()

    pcfg = PipelineConfig(
        task=args.task, seq_len=40, batch_size=32,
        ct_steps=max(40, args.steps // 4), sft_steps=args.steps,
        sft_lr=6e-4, ct_lr=6e-4, log_every=50, eval_batches=8,
        distill=DistillConfig(tau=5.0, lambda_ld=1.0, gamma_ad=10.0,
                              split_heads=2))
    pipe = BitDistillPipeline(CFG, pcfg)

    print("== stage 0: FP16-SFT teacher ==")
    tstate, tres = pipe.train_teacher(jax.random.PRNGKey(0))
    t_acc = pipe.eval_accuracy(tstate.params, quantized=False)
    print(f"teacher acc: {t_acc:.3f}  ({tres.seconds:.0f}s)")

    print("== baseline: BitNet-SFT (no CT, no KD) ==")
    s0 = pipe.refine(tstate.params)
    s_sft, _ = pipe.bitnet_sft(s0)
    sft_acc = pipe.eval_accuracy(s_sft, quantized=True)
    print(f"bitnet-sft acc: {sft_acc:.3f}")

    print("== stage 2: continual pre-training ==")
    s_ct, ctres = pipe.continue_pretrain(s0)
    print(f"ct loss: {ctres.metrics_history[0]['loss']:.3f} -> "
          f"{ctres.final_loss:.3f}")

    print("== stage 3: distillation fine-tuning (CE + λ·LD + γ·AD) ==")
    s_bd, dres = pipe.distill_finetune(s_ct, tstate.params)
    bd_acc = pipe.eval_accuracy(s_bd, quantized=True)

    print("\n== results ==")
    print(f"{'FP16-SFT (teacher)':24s} {t_acc:.3f}")
    print(f"{'BitNet-SFT':24s} {sft_acc:.3f}")
    print(f"{'BitDistill (ours)':24s} {bd_acc:.3f}")
    print(f"gap closed: {bd_acc - sft_acc:+.3f} "
          f"(teacher gap remaining: {t_acc - bd_acc:+.3f})")


if __name__ == "__main__":
    main()
