"""Quickstart: convert a (randomly initialized stand-in) FP model to a
1.58-bit BitDistill student, run one QAT train step, and inspect the
quantized weights.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.models import build_model, get_config
from repro.training.optimizer import AdamW, AdamWConfig
from repro.training.trainer import init_train_state, make_train_step

# 1. pick an architecture (any of the 10 assigned configs, or qwen3-*) and
#    shrink it to laptop scale
cfg = get_config("qwen2.5-3b").reduced()
print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}")

# 2. stage-1 modeling refinement: BitLinear (absmean ternary + int8 acts,
#    STE) and SubLN before every output projection
student_cfg = cfg.with_quant(Q.QAT)
model = build_model(student_cfg)
params = model.init(jax.random.PRNGKey(0))

# 3. one QAT train step (CE loss on random tokens)
opt = AdamW(AdamWConfig())
step = jax.jit(make_train_step(model, opt, lambda s: 1e-4))
state = init_train_state(params, opt)
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab),
    "loss_mask": jnp.ones((4, 32), jnp.float32),
}
state, metrics = step(state, batch)
print(f"loss={float(metrics['loss']):.4f}  grad_norm={float(metrics['grad_norm']):.3f}")

# 4. look at what the quantizer does to one weight matrix
w = state.params["stack"]["pos0"]["attn"]["wq"]["w"][0]
q, delta = Q.weight_quant_absmean(w)
hist = Q.ternary_histogram(w)
print(f"ternary histogram (-1/0/+1): {list(map(int, hist))}  delta={float(delta):.5f}")
print(f"boundary mass: {float(Q.boundary_mass(w)):.4f}")
print("quickstart OK")
