"""Continuous-batching serving of a 1.58-bit student with 2-bit-packed
ternary weights.

Trains a tiny student on the summarization task first (so generations are
meaningful), converts it to the packed serving artifact, then serves requests
through the continuous-batching engine: half the requests are submitted up
front and the rest are injected mid-flight, with tokens streamed as they are
generated.  Reports tokens/s + weight-memory ratio.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.core.distill import DistillConfig
from repro.core.pipeline import BitDistillPipeline, PipelineConfig
from repro.data.synth import get_task
from repro.models.base import ModelConfig
from repro.nn.module import tree_bytes
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, ServeConfig, convert_to_packed

CFG = ModelConfig(name="serve-demo", family="dense", vocab=288, d_model=128,
                  n_layers=3, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                  param_dtype="float32", compute_dtype="float32",
                  remat=False, max_seq=96)


def main():
    pcfg = PipelineConfig(task="cnndm-syn", seq_len=72, batch_size=32,
                          ct_steps=40, sft_steps=200, sft_lr=6e-4,
                          log_every=50,
                          distill=DistillConfig(lambda_ld=1.0, gamma_ad=10.0,
                                                split_heads=2))
    pipe = BitDistillPipeline(CFG, pcfg)
    print("training teacher + distilling student (a few minutes on CPU)...")
    tstate, _ = pipe.train_teacher(jax.random.PRNGKey(0))
    s0 = pipe.refine(tstate.params)
    s_bd, _ = pipe.distill_finetune(s0, tstate.params)

    qat_cfg = pipe.student_config()
    packed_cfg, packed_params = convert_to_packed(qat_cfg, s_bd)
    print(f"weight bytes: qat fp32 {tree_bytes(s_bd)/2**20:.1f} MiB -> "
          f"packed {tree_bytes(packed_params)/2**20:.1f} MiB")

    task = get_task("cnndm-syn")
    rng = np.random.default_rng(0)
    prompts = []
    for i in range(8):
        doc, _ = task.sample(rng, 72)
        prompts.append([task.tok.bos_id] + doc + [task.tok.sep_id])

    plen = max(len(p) for p in prompts)
    eng = Engine(packed_cfg, packed_params,
                 ServeConfig(max_batch=4, max_len=plen + 10,
                             eos_id=task.tok.eos_id))
    sp = SamplingParams(max_tokens=10)
    t0 = time.time()
    reqs = [eng.submit(p, sp) for p in prompts[:4]]
    n, injected = 0, False
    while eng.has_pending() or not injected:
        for out in eng.step():
            n += 1 if out.token >= 0 else 0
        if not injected:   # continuous batching: add load mid-flight
            reqs += [eng.submit(p, sp) for p in prompts[4:]]
            injected = True
    dt = time.time() - t0
    print(f"served {len(reqs)} requests / {n} tokens in {dt:.1f}s "
          f"({n/dt:.1f} tok/s, CPU interpret mode; 4 submitted mid-flight)")
    for r in reqs[:3]:
        print(f"  req {r.uid} [{r.finish_reason.value}]: {r.output_tokens}")


if __name__ == "__main__":
    main()
