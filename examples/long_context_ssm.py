"""Long-context decode with an attention-free (Mamba2/SSD) 1.58-bit student.

Demonstrates why the long_500k shape only runs for SSM/hybrid archs: the
recurrent state is O(1) in sequence length, so decode cost is flat while a
KV cache would grow linearly (and attention quadratically).

    PYTHONPATH=src python examples/long_context_ssm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.models import build_model, get_config
from repro.nn.module import tree_bytes

cfg = get_config("mamba2-780m").reduced().with_quant(Q.QAT)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

B = 2
cache = model.init_cache(params, B, 1, jnp.float32)
print(f"SSM state bytes (seq-independent): {tree_bytes(cache)/2**20:.2f} MiB")

decode = jax.jit(model.decode_step)
tok = jnp.array([1, 2], jnp.int32)
logits, cache = decode(params, tok, cache, jnp.int32(0))  # compile

positions = [0, 1_000, 100_000, 524_288]
t_prev = None
for i, pos in enumerate(positions):
    t0 = time.perf_counter()
    for _ in range(20):
        logits, cache = decode(params, tok, cache, jnp.int32(pos))
    logits.block_until_ready()
    dt = (time.perf_counter() - t0) / 20 * 1e3
    print(f"decode at position {pos:>8d}: {dt:.2f} ms/token "
          f"(state {tree_bytes(cache)/2**20:.2f} MiB)")

# contrast: a dense-attention model's KV cache at 524288 tokens
att = get_config("qwen2.5-3b")
kv_bytes = (att.n_layers * att.n_kv_heads * att.head_dim * 524_288 * 2 * 2)
print(f"\nfor contrast, {att.name} full-precision KV cache at 524k tokens "
      f"would be {kv_bytes/2**30:.1f} GiB per sequence — why long_500k is "
      "SSM/hybrid-only (DESIGN.md §4)")
print("long-context OK")
